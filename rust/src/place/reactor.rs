//! Hand-rolled, dependency-free readiness-reactor primitives for the
//! socket runtime's per-rank I/O event loop.
//!
//! No `mio`/`tokio`/`libc` — like `util/json.rs`, everything here is
//! built from `std` plus direct `extern "C"` bindings to the handful of
//! syscalls `std` itself already links (`epoll_*` on Linux, `poll` as
//! the portable Unix fallback, `writev` everywhere). The pieces:
//!
//! * [`Poller`] — level-triggered readiness multiplexer over raw fds.
//!   On Linux this is one `epoll` instance; elsewhere it degrades to
//!   `poll(2)` over a registration table. Either way the reactor thread
//!   blocks in exactly one syscall for *all* of a rank's mesh + control
//!   sockets, instead of parking one OS thread per link.
//! * [`Waker`] — a nonblocking socketpair that lets worker threads kick
//!   a [`Poller::wait`] out of its sleep after enqueuing frames.
//! * [`OutQueue`] — a per-peer write queue of encoded frames
//!   (`Arc<Vec<u8>>`, so tolerant-mode retention can hold the same
//!   buffer). [`OutQueue::flush`] coalesces queued frames into
//!   `writev` batches — small steal/credit frames that accumulate
//!   while a socket is busy leave in one syscall — and recycles fully
//!   sent buffers into the shared
//!   [`BufferPool`](crate::glb::wire::BufferPool).

use std::collections::VecDeque;
use std::io::{self, Read, Write};
use std::os::unix::io::{AsRawFd, RawFd};
use std::os::unix::net::UnixStream;
use std::sync::{Arc, Mutex, MutexGuard};

use crate::glb::wire::BufferPool;

/// Take a mutex guard, absorbing poison. The reactor's shared state
/// (write queues, poll registrations, steal marks) must stay usable
/// even if some other thread panicked mid-hold: the I/O loop's job at
/// that point is to keep driving teardown, not to amplify one worker's
/// panic into a hung fleet. Every protected structure here is valid
/// after any partial update (queues of whole frames, registration
/// tables), so recovering the guard is sound.
pub(crate) fn lock_clean<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    match m.lock() {
        Ok(guard) => guard,
        Err(poisoned) => poisoned.into_inner(),
    }
}

// ---------------------------------------------------------------------
// syscall surface
// ---------------------------------------------------------------------

/// `struct iovec`, as `writev` expects it.
#[repr(C)]
struct IoVec {
    base: *const u8,
    len: usize,
}

extern "C" {
    fn writev(fd: i32, iov: *const IoVec, iovcnt: i32) -> isize;
}

/// Frames coalesced into a single `writev` call. (Linux `IOV_MAX` is
/// 1024; 64 already amortizes the syscall while keeping the stack cheap.)
const MAX_IOVS: usize = 64;

#[cfg(target_os = "linux")]
mod sys {
    use super::io;
    use super::RawFd;

    pub const EPOLLIN: u32 = 0x001;
    pub const EPOLLOUT: u32 = 0x004;
    pub const EPOLLERR: u32 = 0x008;
    pub const EPOLLHUP: u32 = 0x010;
    pub const EPOLLRDHUP: u32 = 0x2000;

    const EPOLL_CTL_ADD: i32 = 1;
    const EPOLL_CTL_DEL: i32 = 2;
    const EPOLL_CTL_MOD: i32 = 3;
    const EPOLL_CLOEXEC: i32 = 0x80000;

    /// Kernel `struct epoll_event`: packed on x86-64 (the one ABI where
    /// the kernel definition differs from natural alignment).
    #[repr(C)]
    #[cfg_attr(target_arch = "x86_64", repr(packed))]
    #[derive(Clone, Copy)]
    pub struct EpollEvent {
        pub events: u32,
        pub data: u64,
    }

    extern "C" {
        fn epoll_create1(flags: i32) -> i32;
        fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
        fn epoll_wait(epfd: i32, events: *mut EpollEvent, maxevents: i32, timeout: i32) -> i32;
        fn close(fd: i32) -> i32;
    }

    pub struct Backend {
        epfd: RawFd,
    }

    impl Backend {
        pub fn new() -> io::Result<Self> {
            // SAFETY: epoll_create1 takes no pointers; it returns a new
            // fd or -1, and both outcomes are handled below.
            let epfd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
            if epfd < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(Self { epfd })
        }

        fn ctl(&self, op: i32, fd: RawFd, mask: u32, token: u64) -> io::Result<()> {
            let mut ev = EpollEvent { events: mask, data: token };
            let arg = if op == EPOLL_CTL_DEL { std::ptr::null_mut() } else { &mut ev };
            // SAFETY: `arg` is either null (allowed for DEL since Linux
            // 2.6.9) or a live pointer to `ev`, which outlives the call;
            // the kernel only reads it.
            if unsafe { epoll_ctl(self.epfd, op, fd, arg) } < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(())
        }

        pub fn add(&self, fd: RawFd, mask: u32, token: u64) -> io::Result<()> {
            self.ctl(EPOLL_CTL_ADD, fd, mask, token)
        }

        pub fn modify(&self, fd: RawFd, mask: u32, token: u64) -> io::Result<()> {
            self.ctl(EPOLL_CTL_MOD, fd, mask, token)
        }

        pub fn remove(&self, fd: RawFd) -> io::Result<()> {
            self.ctl(EPOLL_CTL_DEL, fd, 0, 0)
        }

        pub fn wait(&self, out: &mut Vec<super::Event>, timeout_ms: i32) -> io::Result<()> {
            let mut buf = [EpollEvent { events: 0, data: 0 }; 64];
            let max = buf.len() as i32;
            let n = loop {
                // SAFETY: `buf` is a live array of `max` initialized
                // events and the kernel writes at most `max` entries
                // into it; `rc` is checked before any entry is read.
                let rc = unsafe { epoll_wait(self.epfd, buf.as_mut_ptr(), max, timeout_ms) };
                if rc >= 0 {
                    break rc as usize;
                }
                let e = io::Error::last_os_error();
                if e.kind() != io::ErrorKind::Interrupted {
                    return Err(e);
                }
            };
            for ev in &buf[..n] {
                // Copy out of the (possibly packed) struct before use.
                let events = ev.events;
                let token = ev.data;
                out.push(super::Event {
                    token,
                    readable: events & (EPOLLIN | EPOLLRDHUP | EPOLLHUP | EPOLLERR) != 0,
                    writable: events & (EPOLLOUT | EPOLLHUP | EPOLLERR) != 0,
                });
            }
            Ok(())
        }
    }

    impl Drop for Backend {
        fn drop(&mut self) {
            // SAFETY: `epfd` was returned by epoll_create1, is owned
            // exclusively by this Backend, and is closed exactly once.
            unsafe { close(self.epfd) };
        }
    }
}

#[cfg(all(unix, not(target_os = "linux")))]
mod sys {
    use super::io;
    use super::Mutex;
    use super::RawFd;

    // Reuse the epoll mask vocabulary so the frontend is identical; the
    // values are translated to poll(2) bits per wait.
    pub const EPOLLIN: u32 = 0x001;
    pub const EPOLLOUT: u32 = 0x004;
    pub const EPOLLERR: u32 = 0x008;
    pub const EPOLLHUP: u32 = 0x010;
    pub const EPOLLRDHUP: u32 = 0x2000;

    const POLLIN: i16 = 0x001;
    const POLLOUT: i16 = 0x004;
    const POLLERR: i16 = 0x008;
    const POLLHUP: i16 = 0x010;
    const POLLNVAL: i16 = 0x020;

    #[repr(C)]
    struct PollFd {
        fd: i32,
        events: i16,
        revents: i16,
    }

    extern "C" {
        fn poll(fds: *mut PollFd, nfds: u32, timeout: i32) -> i32;
    }

    /// `poll(2)` fallback: registrations live in a table, and every wait
    /// rebuilds the pollfd array. O(n) per wake, but n here is a rank's
    /// peer count, and only non-Linux hosts pay it.
    pub struct Backend {
        regs: Mutex<Vec<(RawFd, u32, u64)>>,
    }

    impl Backend {
        pub fn new() -> io::Result<Self> {
            Ok(Self { regs: Mutex::new(Vec::new()) })
        }

        pub fn add(&self, fd: RawFd, mask: u32, token: u64) -> io::Result<()> {
            let mut regs = super::lock_clean(&self.regs);
            if regs.iter().any(|(f, _, _)| *f == fd) {
                return Err(io::Error::new(io::ErrorKind::AlreadyExists, "fd registered"));
            }
            regs.push((fd, mask, token));
            Ok(())
        }

        pub fn modify(&self, fd: RawFd, mask: u32, token: u64) -> io::Result<()> {
            let mut regs = super::lock_clean(&self.regs);
            match regs.iter_mut().find(|(f, _, _)| *f == fd) {
                Some(slot) => {
                    *slot = (fd, mask, token);
                    Ok(())
                }
                None => Err(io::Error::new(io::ErrorKind::NotFound, "fd not registered")),
            }
        }

        pub fn remove(&self, fd: RawFd) -> io::Result<()> {
            let mut regs = super::lock_clean(&self.regs);
            let before = regs.len();
            regs.retain(|(f, _, _)| *f != fd);
            if regs.len() == before {
                return Err(io::Error::new(io::ErrorKind::NotFound, "fd not registered"));
            }
            Ok(())
        }

        pub fn wait(&self, out: &mut Vec<super::Event>, timeout_ms: i32) -> io::Result<()> {
            let snapshot: Vec<(RawFd, u32, u64)> = super::lock_clean(&self.regs).clone();
            let mut fds: Vec<PollFd> = snapshot
                .iter()
                .map(|(fd, mask, _)| {
                    let mut events = 0i16;
                    if mask & (EPOLLIN | EPOLLRDHUP) != 0 {
                        events |= POLLIN;
                    }
                    if mask & EPOLLOUT != 0 {
                        events |= POLLOUT;
                    }
                    PollFd { fd: *fd, events, revents: 0 }
                })
                .collect();
            loop {
                // SAFETY: `fds` is a live Vec of `fds.len()` PollFd
                // entries; the kernel reads `events` and writes
                // `revents` within those bounds only.
                let rc = unsafe { poll(fds.as_mut_ptr(), fds.len() as u32, timeout_ms) };
                if rc >= 0 {
                    break;
                }
                let e = io::Error::last_os_error();
                if e.kind() != io::ErrorKind::Interrupted {
                    return Err(e);
                }
            }
            for (pfd, (_, _, token)) in fds.iter().zip(&snapshot) {
                if pfd.revents == 0 {
                    continue;
                }
                out.push(super::Event {
                    token: *token,
                    readable: pfd.revents & (POLLIN | POLLHUP | POLLERR | POLLNVAL) != 0,
                    writable: pfd.revents & (POLLOUT | POLLHUP | POLLERR | POLLNVAL) != 0,
                });
            }
            Ok(())
        }
    }
}

// ---------------------------------------------------------------------
// poller
// ---------------------------------------------------------------------

/// One readiness notification out of [`Poller::wait`].
#[derive(Debug, Clone, Copy)]
pub struct Event {
    /// The token the fd was registered under (the reactor's connection
    /// index).
    pub token: u64,
    pub readable: bool,
    pub writable: bool,
}

/// Level-triggered readiness multiplexer (`epoll` on Linux, `poll`
/// elsewhere). All methods take `&self`: registration changes may race
/// with a concurrent [`Poller::wait`] by design — that is what epoll is
/// for, and the `poll` fallback snapshots its table per wait.
pub struct Poller {
    backend: sys::Backend,
}

fn interest_mask(readable: bool, writable: bool) -> u32 {
    let mut mask = 0;
    if readable {
        mask |= sys::EPOLLIN | sys::EPOLLRDHUP;
    }
    if writable {
        mask |= sys::EPOLLOUT;
    }
    mask
}

impl Poller {
    pub fn new() -> io::Result<Self> {
        Ok(Self { backend: sys::Backend::new()? })
    }

    pub fn add(&self, fd: RawFd, token: u64, readable: bool, writable: bool) -> io::Result<()> {
        self.backend.add(fd, interest_mask(readable, writable), token)
    }

    pub fn modify(&self, fd: RawFd, token: u64, readable: bool, writable: bool) -> io::Result<()> {
        self.backend.modify(fd, interest_mask(readable, writable), token)
    }

    pub fn remove(&self, fd: RawFd) -> io::Result<()> {
        self.backend.remove(fd)
    }

    /// Block until at least one registered fd is ready (or `timeout_ms`
    /// passes; `-1` = forever), appending notifications to `out`.
    /// Spurious empty returns are allowed — callers must treat `out`
    /// being empty after a wait as "nothing to do", not an error.
    pub fn wait(&self, out: &mut Vec<Event>, timeout_ms: i32) -> io::Result<()> {
        out.clear();
        self.backend.wait(out, timeout_ms)
    }
}

// ---------------------------------------------------------------------
// waker
// ---------------------------------------------------------------------

/// Cross-thread wakeup for a [`Poller`]: worker threads [`Waker::wake`]
/// after enqueuing frames, the reactor registers [`Waker::rx_fd`] for
/// readability and [`Waker::drain`]s it on wake. Wakes coalesce — the
/// socketpair buffer holds at most a few pending bytes, and a full
/// buffer ([`io::ErrorKind::WouldBlock`]) means a wake is already
/// pending, which is exactly the semantics wanted.
pub struct Waker {
    tx: UnixStream,
    rx: UnixStream,
}

impl Waker {
    pub fn new() -> io::Result<Self> {
        let (tx, rx) = UnixStream::pair()?;
        tx.set_nonblocking(true)?;
        rx.set_nonblocking(true)?;
        Ok(Self { tx, rx })
    }

    /// The fd the reactor registers for readability.
    pub fn rx_fd(&self) -> RawFd {
        self.rx.as_raw_fd()
    }

    pub fn wake(&self) {
        // A send error means either a wake is already pending
        // (WouldBlock) or the reactor is gone — both ignorable.
        let _ = (&self.tx).write(&[1]);
    }

    /// Swallow all pending wake bytes (reactor side).
    pub fn drain(&self) {
        let mut buf = [0u8; 64];
        while matches!((&self.rx).read(&mut buf), Ok(n) if n > 0) {}
    }
}

// ---------------------------------------------------------------------
// per-peer write queue with writev batching
// ---------------------------------------------------------------------

/// What one [`OutQueue::flush`] accomplished.
#[derive(Debug, Clone, Copy, Default)]
pub struct FlushOutcome {
    /// Frames written out completely (and recycled into the pool).
    pub frames_done: u64,
    /// Bytes put on the wire.
    pub bytes: u64,
    /// `writev` calls that moved data (each is one coalesced batch).
    pub batches: u64,
    /// The socket refused more data; the caller should arm `EPOLLOUT`.
    pub blocked: bool,
    /// The queue is closed *and* empty: safe to half-close the socket.
    pub drained: bool,
}

struct OutInner {
    frames: VecDeque<Arc<Vec<u8>>>,
    /// Bytes of the head frame already written (partial-write cursor).
    head_off: usize,
    closing: bool,
}

/// A per-peer queue of encoded wire frames awaiting the reactor.
/// Senders [`OutQueue::push`] whole frames (each an `Arc` so
/// tolerant-mode retention can alias the buffer); the reactor thread
/// [`OutQueue::flush`]es them in `writev` batches whenever the socket
/// is writable. After [`OutQueue::close`], pushes are refused and the
/// queue drains to its end — frame boundaries are never torn.
pub struct OutQueue {
    inner: Mutex<OutInner>,
}

impl Default for OutQueue {
    fn default() -> Self {
        Self::new()
    }
}

impl OutQueue {
    pub fn new() -> Self {
        Self { inner: Mutex::new(OutInner { frames: VecDeque::new(), head_off: 0, closing: false }) }
    }

    /// Enqueue a frame. Returns `false` (frame dropped) once the queue
    /// is closing — teardown refuses new traffic the same way a dead
    /// link used to.
    pub fn push(&self, frame: Arc<Vec<u8>>) -> bool {
        let mut inner = lock_clean(&self.inner);
        if inner.closing {
            return false;
        }
        inner.frames.push_back(frame);
        true
    }

    /// Refuse further pushes; the reactor drains what is queued, then
    /// reports `drained` so the socket can be half-closed.
    pub fn close(&self) {
        lock_clean(&self.inner).closing = true;
    }

    pub fn is_empty(&self) -> bool {
        lock_clean(&self.inner).frames.is_empty()
    }

    /// Frames currently queued (the live-telemetry out-queue-depth gauge).
    pub fn len(&self) -> usize {
        lock_clean(&self.inner).frames.len()
    }

    /// Write as much queued data as the socket accepts, coalescing up
    /// to [`MAX_IOVS`] frames per `writev`. Nonblocking: stops (with
    /// `blocked`) the moment the socket would block. Fully written
    /// frames are recycled into `pool`.
    pub fn flush(&self, fd: RawFd, pool: &BufferPool) -> io::Result<FlushOutcome> {
        let mut out = FlushOutcome::default();
        let mut inner = lock_clean(&self.inner);
        loop {
            if inner.frames.is_empty() {
                out.drained = inner.closing;
                return Ok(out);
            }
            let mut iovs: Vec<IoVec> = Vec::with_capacity(inner.frames.len().min(MAX_IOVS));
            for (i, f) in inner.frames.iter().take(MAX_IOVS).enumerate() {
                let off = if i == 0 { inner.head_off } else { 0 };
                iovs.push(IoVec { base: f[off..].as_ptr(), len: f.len() - off });
            }
            let written = loop {
                // SAFETY: each iovec points into an `Arc<Vec<u8>>` held
                // by `inner.frames` for the whole call (the queue lock is
                // held, so no frame is popped or recycled concurrently),
                // and `len` never exceeds the frame's remaining bytes.
                let rc = unsafe { writev(fd, iovs.as_ptr(), iovs.len() as i32) };
                if rc >= 0 {
                    break rc as usize;
                }
                let e = io::Error::last_os_error();
                match e.kind() {
                    io::ErrorKind::Interrupted => continue,
                    io::ErrorKind::WouldBlock => {
                        out.blocked = true;
                        return Ok(out);
                    }
                    _ => return Err(e),
                }
            };
            if written == 0 {
                out.blocked = true;
                return Ok(out);
            }
            out.batches += 1;
            out.bytes += written as u64;
            let mut left = written;
            while left > 0 {
                // writev never reports more than it was handed, so the
                // head frame is present for every byte being accounted;
                // a bare `break` (not a panic) guards the impossible.
                let Some(head) = inner.frames.front() else { break };
                let head_remaining = head.len() - inner.head_off;
                if left >= head_remaining {
                    left -= head_remaining;
                    inner.head_off = 0;
                    if let Some(done) = inner.frames.pop_front() {
                        pool.put_arc(done);
                        out.frames_done += 1;
                    }
                } else {
                    inner.head_off += left;
                    left = 0;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn pair() -> (UnixStream, UnixStream) {
        UnixStream::pair().expect("socketpair")
    }

    #[test]
    fn poller_reports_writable_then_readable() {
        let poller = Poller::new().unwrap();
        let (a, b) = pair();
        a.set_nonblocking(true).unwrap();
        poller.add(a.as_raw_fd(), 7, true, true).unwrap();

        let mut evs = Vec::new();
        poller.wait(&mut evs, 1000).unwrap();
        assert!(evs.iter().any(|e| e.token == 7 && e.writable), "{evs:?}");
        assert!(!evs.iter().any(|e| e.token == 7 && e.readable), "{evs:?}");

        // Drop write interest: an idle socket is silent.
        poller.modify(a.as_raw_fd(), 7, true, false).unwrap();
        poller.wait(&mut evs, 50).unwrap();
        assert!(evs.is_empty(), "{evs:?}");

        (&b).write_all(b"x").unwrap();
        poller.wait(&mut evs, 1000).unwrap();
        assert!(evs.iter().any(|e| e.token == 7 && e.readable), "{evs:?}");

        poller.remove(a.as_raw_fd()).unwrap();
        poller.wait(&mut evs, 50).unwrap();
        assert!(evs.is_empty(), "{evs:?}");
    }

    #[test]
    fn poller_sees_peer_hangup_as_readable() {
        let poller = Poller::new().unwrap();
        let (a, b) = pair();
        poller.add(a.as_raw_fd(), 1, true, false).unwrap();
        drop(b);
        let mut evs = Vec::new();
        poller.wait(&mut evs, 1000).unwrap();
        assert!(evs.iter().any(|e| e.readable), "EOF must surface as readable: {evs:?}");
    }

    #[test]
    fn waker_wakes_a_sleeping_poller_and_coalesces() {
        let poller = Arc::new(Poller::new().unwrap());
        let waker = Arc::new(Waker::new().unwrap());
        poller.add(waker.rx_fd(), 0, true, false).unwrap();

        let w = Arc::clone(&waker);
        let kicker = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            w.wake();
            w.wake(); // double wake must coalesce
        });
        let mut evs = Vec::new();
        poller.wait(&mut evs, 5000).unwrap();
        assert!(evs.iter().any(|e| e.token == 0 && e.readable), "{evs:?}");
        kicker.join().unwrap();

        waker.drain();
        poller.wait(&mut evs, 50).unwrap();
        assert!(evs.is_empty(), "drained waker must go quiet: {evs:?}");
    }

    #[test]
    fn out_queue_batches_frames_into_one_writev() {
        let (tx, rx) = pair();
        tx.set_nonblocking(true).unwrap();
        let q = OutQueue::new();
        let pool = BufferPool::new();
        let frames: Vec<Vec<u8>> = vec![vec![1, 2, 3], vec![4], vec![5, 6, 7, 8, 9]];
        for f in &frames {
            assert!(q.push(Arc::new(f.clone())));
        }
        let out = q.flush(tx.as_raw_fd(), &pool).unwrap();
        assert_eq!(out.frames_done, 3);
        assert_eq!(out.batches, 1, "3 small frames must leave in one writev");
        assert_eq!(out.bytes, 9);
        assert!(!out.blocked);
        assert_eq!(pool.pooled(), 3, "flushed frames return to the pool");

        let mut got = vec![0u8; 9];
        (&rx).read_exact(&mut got).unwrap();
        assert_eq!(got, frames.concat(), "byte order and boundaries preserved");
        assert!(q.is_empty());
    }

    #[test]
    fn out_queue_survives_partial_writes_and_drains_after_close() {
        let (tx, rx) = pair();
        tx.set_nonblocking(true).unwrap();
        rx.set_nonblocking(true).unwrap();
        let q = OutQueue::new();
        let pool = BufferPool::new();
        // Far more than a socketpair buffer: forces blocked flushes and
        // partial-frame write cursors.
        let frame = Arc::new((0..=255u8).cycle().take(1 << 20).collect::<Vec<u8>>());
        let total: usize = 4 * frame.len();
        for _ in 0..4 {
            assert!(q.push(Arc::clone(&frame)));
        }
        q.close();
        assert!(!q.push(Arc::new(vec![1])), "closed queue refuses frames");

        let first = q.flush(tx.as_raw_fd(), &pool).unwrap();
        assert!(first.blocked, "4 MiB cannot fit a socketpair buffer");

        let mut received = Vec::with_capacity(total);
        let mut buf = vec![0u8; 64 * 1024];
        let mut drained = false;
        while received.len() < total {
            if !drained {
                drained = q.flush(tx.as_raw_fd(), &pool).unwrap().drained;
            }
            match (&rx).read(&mut buf) {
                Ok(n) => received.extend_from_slice(&buf[..n]),
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => continue,
                Err(e) => panic!("read: {e}"),
            }
        }
        assert!(q.flush(tx.as_raw_fd(), &pool).unwrap().drained);
        for chunk in received.chunks(frame.len()) {
            assert_eq!(chunk, &frame[..], "frame boundaries survive partial writes");
        }
    }

    #[test]
    fn empty_open_queue_is_not_drained() {
        let (tx, _rx) = pair();
        tx.set_nonblocking(true).unwrap();
        let q = OutQueue::new();
        let pool = BufferPool::new();
        let out = q.flush(tx.as_raw_fd(), &pool).unwrap();
        assert!(!out.drained, "only a *closed* empty queue may half-close the socket");
        q.close();
        assert!(q.flush(tx.as_raw_fd(), &pool).unwrap().drained);
    }
}
