//! Thread-based place runtime.
//!
//! The closest single-machine analogue of X10 places: one OS thread per
//! place, strictly message-passing communication (every inter-place
//! interaction moves values through an mpsc mailbox; no task state is
//! shared), and by-value loot transfer enforced by `Send`.
//!
//! The runtime drives the shared [`Worker`] protocol engine:
//!
//! * `Working` places drain their mailbox (answering steals — the paper's
//!   "probes the network" between `process(n)` calls), then run one chunk;
//! * waiting/idle places block on their mailbox;
//! * the place that observes global quiescence broadcasts `Terminate`.
//!
//! Setup is fully sequential (queues built, workers constructed, empty
//! workers kicked into the steal protocol) **before** any thread runs, so
//! the token ledger is complete when the first message flows — see
//! `glb::termination` for why that matters.
//!
//! The sibling [`socket`] runtime lifts the same engine across OS
//! *processes*: one process per GLB node, messages as length-prefixed
//! TCP frames ([`crate::glb::wire`]) on direct spoke-to-spoke mesh
//! links, credit-based distributed termination, and a fleet-wide start
//! barrier that recreates this sequential-setup guarantee distributedly.

pub mod membership;
pub mod network;
pub mod reactor;
pub mod runtime;
pub mod service;
pub mod socket;

pub use membership::{DynamicMembership, FixedMembership, MembershipProvider, MembershipView};
pub use network::Transport;
pub use runtime::{run_threads, run_threads_opts, ThreadRunOpts};
pub use service::{
    serve, serve_with, JobApp, JobReport, JobSpec, ServiceBag, ServiceQueue, ServiceReducer,
    ServiceResult, SubmitClient,
};
pub use socket::{
    cross_epoch_frames, io_threads_live, io_threads_spawned, misrouted_frames, net_stats,
    run_sockets, run_sockets_reduced, wire_bytes, NetStats, SocketRunOpts,
};
