//! Figure regeneration (paper Figs 2–10).
//!
//! Each function sweeps place counts under an architecture profile and
//! prints the same series the paper plots. The simulator substrate runs
//! the real protocol + real app compute on a virtual clock, so the
//! *shapes* (linear speedup, flat efficiency, the K droop, the workload
//! distribution flattening) are reproduced; absolute rates are anchored
//! by the calibrated cost model but are not the comparison target.
//!
//! * Figs 2/3/4 — UTS vs UTS-G throughput + efficiency on
//!   Power 775 / BGQ / K ([`fig_uts`]).
//! * Figs 5/7/9 — BC vs BC-G throughput + efficiency on
//!   BGQ / K / Power 775 ([`fig_bc_perf`]).
//! * Figs 6/8/10 — BC vs BC-G per-place workload distribution with
//!   mean/σ ([`fig_bc_workload`]).

use std::sync::Arc;

use super::calibrate::{calibrate_bc_cost, calibrate_uts_cost};
use super::table::Table;
use crate::apps::bc::{Graph, InterruptibleBcQueue, RmatParams};
use crate::apps::uts::{UtsParams, UtsQueue};
use crate::baselines::legacy_bc::run_legacy_bc_sim;
use crate::baselines::legacy_uts::legacy_uts_params;
use crate::glb::task_queue::{SumReducer, VecSumReducer};
use crate::glb::{GlbConfig, GlbParams};
use crate::sim::{run_sim, ArchProfile, CostModel};
use crate::util::stats::{mean, stddev};

/// Options shared by the figure sweeps.
#[derive(Debug, Clone)]
pub struct FigOpts {
    /// Place counts to sweep (the paper's x axis).
    pub places: Vec<usize>,
    /// UTS depth at one place. Like the paper ("tree depth d varying
    /// from 13 to 20 depending on core counts"), the sweep grows the
    /// depth with the place count — `d(p) = uts_depth + ceil(log4 p)` —
    /// so per-place work stays roughly constant (the geometric tree's
    /// expected size is `b0^d` and `b0 = 4`). Strong-scaling a fixed
    /// small tree to thousands of places would measure only ramp-up.
    pub uts_depth: u32,
    /// R-MAT SCALE for the BC figures.
    pub bc_scale: u32,
    /// GLB parameters for the GLB series.
    pub params: GlbParams,
    /// Emit CSV instead of the aligned table.
    pub csv: bool,
}

impl Default for FigOpts {
    fn default() -> Self {
        Self {
            places: vec![1, 2, 4, 8, 16, 32, 64, 128, 256],
            uts_depth: 8,
            bc_scale: 9,
            params: GlbParams::default(),
            csv: false,
        }
    }
}

/// One point of a perf series.
#[derive(Debug, Clone, Copy)]
pub struct PerfPoint {
    pub places: usize,
    /// units/s (UTS: nodes/s; BC: edges/s).
    pub rate: f64,
    /// rate / places / single-place-rate.
    pub efficiency: f64,
}

/// A complete figure: the two series plus rendered text.
#[derive(Debug, Clone)]
pub struct Figure {
    pub title: String,
    pub legacy: Vec<PerfPoint>,
    pub glb: Vec<PerfPoint>,
    pub text: String,
}

/// Figs 2/3/4: UTS (legacy-tuned params) vs UTS-G (library defaults) on
/// one architecture.
pub fn fig_uts(arch: &ArchProfile, opts: &FigOpts) -> Figure {
    let up = UtsParams { b0: 4.0, seed: 19, max_depth: opts.uts_depth };
    let cost = calibrate_uts_cost();
    let legacy = sweep_uts(arch, opts, &up, cost, legacy_uts_params());
    let glb = sweep_uts(arch, opts, &up, cost, opts.params);
    render_perf_figure(
        format!("UTS/UTS-G Performance Comparison (on {})", arch.name),
        "nodes/s",
        legacy,
        glb,
        opts.csv,
    )
}

/// `ceil(log4(p))` — extra tree depth needed to keep per-place work
/// constant when `b0 = 4`.
fn depth_boost(p: usize) -> u32 {
    let mut d = 0u32;
    let mut cap = 1usize;
    while cap < p {
        cap *= 4;
        d += 1;
    }
    d
}

fn sweep_uts(
    arch: &ArchProfile,
    opts: &FigOpts,
    up: &UtsParams,
    cost: CostModel,
    params: GlbParams,
) -> Vec<PerfPoint> {
    let mut base_rate = None;
    let mut out = Vec::new();
    for &p in &opts.places {
        let scaled = UtsParams { max_depth: up.max_depth + depth_boost(p), ..*up };
        let cfg = GlbConfig::new(p, params);
        let (run, _) = run_sim(
            &cfg,
            arch,
            cost,
            |_, _| UtsQueue::new(scaled),
            |q| q.init_root(),
            &SumReducer,
        );
        let rate = run.units_per_sec();
        let base = *base_rate.get_or_insert(rate.max(1e-9));
        out.push(PerfPoint { places: p, rate, efficiency: rate / p as f64 / base });
    }
    out
}

/// Figs 5/7/9: BC (static randomized) vs BC-G on one architecture.
pub fn fig_bc_perf(arch: &ArchProfile, opts: &FigOpts) -> Figure {
    let g = Arc::new(Graph::rmat(RmatParams { scale: opts.bc_scale, ..Default::default() }));
    let cost = calibrate_bc_cost(&g);
    let mut legacy = Vec::new();
    let mut glb = Vec::new();
    let (mut base_l, mut base_g) = (None, None);
    for &p in &opts.places {
        // Legacy: zero-communication static randomized partition.
        let lo = run_legacy_bc_sim(&g, p, 42, cost.ns_per_unit, arch.compute_scale);
        let lrate = lo.units_per_sec();
        let lbase = *base_l.get_or_insert(lrate.max(1e-9));
        legacy.push(PerfPoint { places: p, rate: lrate, efficiency: lrate / p as f64 / lbase });

        // GLB: every place statically seeded, stealing fixes the skew.
        // BC-G is the paper's *final* variant: the interruptible-vertex
        // state machine (§2.6.2) with an edge budget per chunk.
        let cfg = GlbConfig::new(p, opts.params);
        let n = g.n() as u32;
        let gg = g.clone();
        let (run, _) = run_sim(
            &cfg,
            arch,
            cost,
            move |i, np| {
                let mut q = InterruptibleBcQueue::new(gg.clone());
                let per = n / np as u32;
                let lo = i as u32 * per;
                let hi = if i == np - 1 { n } else { lo + per };
                q.assign(lo, hi);
                q
            },
            |_| {},
            &VecSumReducer,
        );
        let grate = run.units_per_sec();
        let gbase = *base_g.get_or_insert(grate.max(1e-9));
        glb.push(PerfPoint { places: p, rate: grate, efficiency: grate / p as f64 / gbase });
    }
    render_perf_figure(
        format!("BC/BC-G Performance (on {})", arch.name),
        "edges/s",
        legacy,
        glb,
        opts.csv,
    )
}

/// Figs 6/8/10: per-place busy-time distribution for legacy BC vs BC-G
/// at a fixed place count (the sweep's largest), with mean and σ.
pub fn fig_bc_workload(arch: &ArchProfile, opts: &FigOpts) -> (Table, String) {
    let p = *opts.places.last().expect("need at least one place count");
    let g = Arc::new(Graph::rmat(RmatParams { scale: opts.bc_scale, ..Default::default() }));
    let cost = calibrate_bc_cost(&g);

    let legacy = run_legacy_bc_sim(&g, p, 42, cost.ns_per_unit, arch.compute_scale);
    let legacy_secs: Vec<f64> = legacy.busy_ns.iter().map(|&x| x as f64 / 1e9).collect();

    let cfg = GlbConfig::new(p, opts.params);
    let n = g.n() as u32;
    let gg = g.clone();
    let (run, _) = run_sim(
        &cfg,
        arch,
        cost,
        move |i, np| {
            let mut q = InterruptibleBcQueue::new(gg.clone());
            let per = n / np as u32;
            let lo = i as u32 * per;
            let hi = if i == np - 1 { n } else { lo + per };
            q.assign(lo, hi);
            q
        },
        |_| {},
        &VecSumReducer,
    );
    let glb_secs: Vec<f64> = run.log.per_place.iter().map(|s| s.process_ns as f64 / 1e9).collect();

    let mut t = Table::new(&["place", "BC busy (s)", "BC-G busy (s)"]);
    for i in 0..p {
        t.row(&[i.to_string(), format!("{:.6}", legacy_secs[i]), format!("{:.6}", glb_secs[i])]);
    }
    let summary = format!(
        "BC/BC-G Workload Distribution (on {}) at {p} places\n\
         BC   : mean={:.4}s sd={:.4}s makespan={:.4}s\n\
         BC-G : mean={:.4}s sd={:.4}s makespan={:.4}s (virtual total {:.4}s)",
        arch.name,
        mean(&legacy_secs),
        stddev(&legacy_secs),
        legacy.elapsed_ns as f64 / 1e9,
        mean(&glb_secs),
        stddev(&glb_secs),
        glb_secs.iter().cloned().fold(0.0, f64::max),
        run.elapsed_ns as f64 / 1e9,
    );
    (t, summary)
}

fn render_perf_figure(
    title: String,
    unit: &str,
    legacy: Vec<PerfPoint>,
    glb: Vec<PerfPoint>,
    csv: bool,
) -> Figure {
    let mut t = Table::new(&[
        "places",
        &format!("legacy {unit}"),
        "legacy eff",
        &format!("GLB {unit}"),
        "GLB eff",
    ]);
    for (l, g) in legacy.iter().zip(&glb) {
        debug_assert_eq!(l.places, g.places);
        t.row(&[
            l.places.to_string(),
            format!("{:.3e}", l.rate),
            format!("{:.3}", l.efficiency),
            format!("{:.3e}", g.rate),
            format!("{:.3}", g.efficiency),
        ]);
    }
    let body = if csv { t.to_csv() } else { t.render() };
    let text = format!("# {title}\n{body}");
    Figure { title, legacy, glb, text }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{BGQ, POWER775};

    fn small_opts() -> FigOpts {
        FigOpts {
            places: vec![1, 4, 16],
            // Depth 8 ≈ 90K nodes: enough parallel slack for 16 places
            // while keeping the test under a second.
            uts_depth: 8,
            bc_scale: 6,
            params: GlbParams::default().with_n(64).with_l(2),
            csv: false,
        }
    }

    #[test]
    fn uts_figure_has_both_series() {
        let f = fig_uts(&POWER775, &small_opts());
        assert_eq!(f.legacy.len(), 3);
        assert_eq!(f.glb.len(), 3);
        assert!(f.text.contains("UTS/UTS-G"));
        // Efficiency at P=1 is 1.0 by construction.
        assert!((f.glb[0].efficiency - 1.0).abs() < 1e-9);
        // Throughput grows with places.
        assert!(f.glb[2].rate > f.glb[0].rate * 4.0);
    }

    #[test]
    fn bc_perf_figure_runs() {
        let f = fig_bc_perf(&BGQ, &small_opts());
        assert_eq!(f.glb.len(), 3);
        assert!(f.glb[1].rate > f.glb[0].rate, "BC-G must scale");
    }

    #[test]
    fn bc_workload_figure_flattens() {
        let (t, summary) = fig_bc_workload(&BGQ, &small_opts());
        assert!(!t.is_empty());
        assert!(summary.contains("sd="), "{summary}");
    }
}
