//! Plain-text table rendering for harness output (no external crates).

/// A simple right-aligned column table with a header row.
#[derive(Debug, Default, Clone)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Self { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render with per-column widths.
    pub fn render(&self) -> String {
        let cols = self.header.len();
        let mut width = vec![0usize; cols];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = h.len();
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                width[i] = width[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], width: &[usize]| -> String {
            let mut line = String::new();
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    line.push_str("  ");
                }
                line.push_str(&format!("{c:>w$}", w = width[i]));
            }
            line
        };
        out.push_str(&fmt_row(&self.header, &width));
        out.push('\n');
        let total: usize = width.iter().sum::<usize>() + 2 * (cols - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r, &width));
            out.push('\n');
        }
        out
    }

    /// Render as CSV (for plotting scripts).
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        out.push_str(&self.header.join(","));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&r.join(","));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["places", "rate"]);
        t.row(&["1".into(), "10.5".into()]);
        t.row(&["1024".into(), "9.8".into()]);
        let s = t.render();
        assert!(s.contains("places"));
        assert!(s.lines().count() == 4);
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines[2].len(), lines[3].len(), "rows align");
    }

    #[test]
    fn csv_output() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        assert_eq!(t.to_csv(), "a,b\n1,2\n");
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_checked() {
        let mut t = Table::new(&["a"]);
        t.row(&["1".into(), "2".into()]);
    }
}
