//! The figure-regeneration harness (paper §3.4–§3.6).
//!
//! Every figure in the paper's evaluation maps to a function here that
//! sweeps the place counts, runs GLB and the legacy comparator under the
//! right architecture profile, and prints the series the paper plots
//! (throughput on the primary axis, efficiency on the secondary axis,
//! or the per-place workload-distribution bars with mean/σ).

pub mod calibrate;
pub mod figures;
pub mod table;

pub use calibrate::{calibrate_bc_cost, calibrate_uts_cost};
pub use figures::{fig_bc_perf, fig_bc_workload, fig_uts, FigOpts};
pub use table::Table;
