//! Cost-model calibration: measure this machine's real per-unit compute
//! costs so the simulator's virtual clock is anchored to reality.
//!
//! The reference core for [`crate::sim::CostModel`] is *this* CPU; the
//! architecture profiles then scale by their `compute_scale`. Calibration
//! keeps the simulator honest: UTS nodes/s and BC edges/s at P=1 in the
//! simulator match a real single-threaded run within measurement noise
//! (asserted by `rust/tests/sim_integration.rs`).

use std::time::Instant;

use crate::apps::bc::{brandes_source, BrandesScratch, Graph};
use crate::apps::uts::{UtsBag, UtsParams, UtsTree};
use crate::sim::CostModel;

/// Serialized bytes of one UTS frontier entry (20-byte descriptor +
/// depth + lo + hi).
pub const UTS_ITEM_BYTES: usize = 32;
/// Serialized bytes of one BC interval task.
pub const BC_ITEM_BYTES: usize = 8;

/// Measure ns per UTS node on this machine (SHA-1 expansion dominated).
pub fn calibrate_uts_cost() -> CostModel {
    let up = UtsParams { b0: 4.0, seed: 19, max_depth: 6 };
    let tree = UtsTree::new(up);
    // Warm-up + measure.
    let mut best = f64::INFINITY;
    for _ in 0..3 {
        let mut bag = UtsBag::with_root(&tree);
        let mut count = 1u64;
        let t = Instant::now();
        loop {
            let (c, more) = bag.expand_some(&tree, 1 << 14);
            count += c;
            if !more {
                break;
            }
        }
        let ns = t.elapsed().as_nanos() as f64 / count as f64;
        best = best.min(ns);
    }
    CostModel::new(best, 60, UTS_ITEM_BYTES)
}

/// Measure ns per BC edge on this machine (sparse Brandes).
pub fn calibrate_bc_cost(g: &Graph) -> CostModel {
    let mut bc = vec![0.0; g.n()];
    let mut scratch = BrandesScratch::new(g.n());
    let sources = (g.n() / 8).max(4).min(g.n());
    let mut best = f64::INFINITY;
    for _ in 0..2 {
        let mut edges = 0u64;
        let t = Instant::now();
        for s in 0..sources as u32 {
            edges += brandes_source(g, s, &mut bc, &mut scratch);
        }
        if edges > 0 {
            best = best.min(t.elapsed().as_nanos() as f64 / edges as f64);
        }
    }
    if !best.is_finite() {
        best = 5.0; // all-isolated fallback
    }
    CostModel::new(best, 80, BC_ITEM_BYTES)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::bc::RmatParams;

    #[test]
    fn uts_cost_is_plausible() {
        let c = calibrate_uts_cost();
        // SHA-1 per node: somewhere between 20ns and 20µs on any machine.
        assert!(c.ns_per_unit > 20.0 && c.ns_per_unit < 20_000.0, "{}", c.ns_per_unit);
    }

    #[test]
    fn bc_cost_is_plausible() {
        let g = Graph::rmat(RmatParams { scale: 8, ..Default::default() });
        let c = calibrate_bc_cost(&g);
        assert!(c.ns_per_unit > 0.2 && c.ns_per_unit < 5_000.0, "{}", c.ns_per_unit);
    }
}
