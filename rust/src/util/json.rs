//! A minimal JSON value type with a renderer and a strict parser.
//!
//! The offline registry has no `serde`, and the fleet reports
//! ([`crate::launch`]) need machine-readable output that external tools
//! (CI scripts, plotters) can parse — so this module hand-rolls the
//! subset of JSON the reports use: objects with ordered keys, arrays,
//! strings, booleans, null, and numbers split into [`Value::Int`]
//! (exact, for counters like UTS node counts that must round-trip
//! bit-identically) and [`Value::Float`] (wall times).
//!
//! Rendering is compact (no whitespace) except for [`Value::render_pretty`];
//! parsing is strict: trailing garbage, unterminated literals, and
//! non-JSON escapes are errors carrying a byte offset.

use std::fmt::Write as _;

/// A parsed or to-be-rendered JSON document.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    /// Integral number (rendered without a decimal point). Counters must
    /// use this variant: `Float` cannot represent `u64` counts exactly.
    Int(i64),
    Float(f64),
    Str(String),
    Arr(Vec<Value>),
    /// Object with insertion-ordered keys (reports render
    /// deterministically; duplicates are a parse error).
    Obj(Vec<(String, Value)>),
}

impl Value {
    /// Object constructor from `(key, value)` pairs.
    pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
        Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Member lookup on an object (`None` for non-objects/missing keys).
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Value::Int(n) if *n >= 0 => Some(*n as u64),
            _ => None,
        }
    }

    /// Numeric view: ints widen to f64 (exact below 2^53).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Int(n) => Some(*n as f64),
            Value::Float(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s.as_str()),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(xs) => Some(xs.as_slice()),
            _ => None,
        }
    }

    /// Compact rendering (single line, no spaces) — one report per line.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.render_into(&mut out);
        out
    }

    /// Two-space-indented rendering for files meant to be read by humans
    /// (committed baselines, `--report` output).
    pub fn render_pretty(&self) -> String {
        let mut out = String::new();
        self.render_pretty_into(&mut out, 0);
        out.push('\n');
        out
    }

    fn render_into(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Int(n) => {
                let _ = write!(out, "{n}");
            }
            Value::Float(x) => render_float(*x, out),
            Value::Str(s) => render_string(s, out),
            Value::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.render_into(out);
                }
                out.push(']');
            }
            Value::Obj(pairs) => {
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    render_string(k, out);
                    out.push(':');
                    v.render_into(out);
                }
                out.push('}');
            }
        }
    }

    fn render_pretty_into(&self, out: &mut String, depth: usize) {
        match self {
            Value::Arr(xs) if !xs.is_empty() => {
                out.push_str("[\n");
                for (i, x) in xs.iter().enumerate() {
                    indent(out, depth + 1);
                    x.render_pretty_into(out, depth + 1);
                    out.push_str(if i + 1 < xs.len() { ",\n" } else { "\n" });
                }
                indent(out, depth);
                out.push(']');
            }
            Value::Obj(pairs) if !pairs.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in pairs.iter().enumerate() {
                    indent(out, depth + 1);
                    render_string(k, out);
                    out.push_str(": ");
                    v.render_pretty_into(out, depth + 1);
                    out.push_str(if i + 1 < pairs.len() { ",\n" } else { "\n" });
                }
                indent(out, depth);
                out.push('}');
            }
            other => other.render_into(out),
        }
    }

    /// Strict parse of a complete JSON document (trailing garbage is an
    /// error). Errors name the byte offset.
    pub fn parse(text: &str) -> Result<Value, String> {
        let bytes = text.as_bytes();
        let mut pos = 0usize;
        let v = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            return Err(format!("trailing bytes after JSON value at offset {pos}"));
        }
        Ok(v)
    }
}

fn indent(out: &mut String, depth: usize) {
    for _ in 0..depth {
        out.push_str("  ");
    }
}

fn render_float(x: f64, out: &mut String) {
    if x.is_finite() {
        // `{}` prints the shortest representation that round-trips; force
        // a decimal point so the parser reads the value back as Float.
        let s = format!("{x}");
        out.push_str(&s);
        if !s.contains(['.', 'e', 'E']) {
            out.push_str(".0");
        }
    } else {
        // JSON has no NaN/Infinity.
        out.push_str("null");
    }
}

fn render_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn expect(b: &[u8], pos: &mut usize, lit: &str) -> Result<(), String> {
    if b[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(())
    } else {
        Err(format!("expected {lit:?} at offset {pos}", pos = *pos))
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Value, String> {
    skip_ws(b, pos);
    match b.get(*pos) {
        None => Err("unexpected end of input".into()),
        Some(&b'n') => expect(b, pos, "null").map(|_| Value::Null),
        Some(&b't') => expect(b, pos, "true").map(|_| Value::Bool(true)),
        Some(&b'f') => expect(b, pos, "false").map(|_| Value::Bool(false)),
        Some(&b'"') => parse_string(b, pos).map(Value::Str),
        Some(&b'[') => {
            *pos += 1;
            let mut xs = Vec::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b']') {
                *pos += 1;
                return Ok(Value::Arr(xs));
            }
            loop {
                xs.push(parse_value(b, pos)?);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(&b',') => *pos += 1,
                    Some(&b']') => {
                        *pos += 1;
                        return Ok(Value::Arr(xs));
                    }
                    _ => return Err(format!("expected ',' or ']' at offset {pos}", pos = *pos)),
                }
            }
        }
        Some(&b'{') => {
            *pos += 1;
            let mut pairs: Vec<(String, Value)> = Vec::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b'}') {
                *pos += 1;
                return Ok(Value::Obj(pairs));
            }
            loop {
                skip_ws(b, pos);
                let key = parse_string(b, pos)?;
                if pairs.iter().any(|(k, _)| *k == key) {
                    return Err(format!("duplicate object key {key:?}"));
                }
                skip_ws(b, pos);
                if b.get(*pos) != Some(&b':') {
                    return Err(format!("expected ':' at offset {pos}", pos = *pos));
                }
                *pos += 1;
                let v = parse_value(b, pos)?;
                pairs.push((key, v));
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(&b',') => *pos += 1,
                    Some(&b'}') => {
                        *pos += 1;
                        return Ok(Value::Obj(pairs));
                    }
                    _ => return Err(format!("expected ',' or '}}' at offset {pos}", pos = *pos)),
                }
            }
        }
        Some(c) if c.is_ascii_digit() || *c == b'-' => parse_number(b, pos),
        Some(c) => Err(format!("unexpected byte {c:?} at offset {pos}", pos = *pos)),
    }
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String, String> {
    if b.get(*pos) != Some(&b'"') {
        return Err(format!("expected '\"' at offset {pos}", pos = *pos));
    }
    *pos += 1;
    let mut out = String::new();
    loop {
        match b.get(*pos) {
            None => return Err("unterminated string".into()),
            Some(&b'"') => {
                *pos += 1;
                return Ok(out);
            }
            Some(&b'\\') => {
                *pos += 1;
                let esc = *b.get(*pos).ok_or("unterminated escape")?;
                *pos += 1;
                match esc {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'u' => {
                        let hex = b.get(*pos..*pos + 4).ok_or("truncated \\u escape")?;
                        let hex = std::str::from_utf8(hex).map_err(|_| "non-ascii \\u escape")?;
                        let n = u32::from_str_radix(hex, 16)
                            .map_err(|_| format!("bad \\u escape {hex:?}"))?;
                        *pos += 4;
                        // Reports never emit surrogate pairs; reject them
                        // rather than decode astral plane pairs.
                        out.push(
                            char::from_u32(n)
                                .ok_or_else(|| format!("\\u{hex} is not a scalar value"))?,
                        );
                    }
                    other => return Err(format!("bad escape \\{}", other as char)),
                }
            }
            Some(_) => {
                // Consume one UTF-8 character (input is a &str, so the
                // byte stream is valid UTF-8 by construction).
                let s = std::str::from_utf8(&b[*pos..]).map_err(|e| e.to_string())?;
                let c = s.chars().next().unwrap();
                if (c as u32) < 0x20 {
                    return Err(format!("raw control byte in string at offset {pos}", pos = *pos));
                }
                out.push(c);
                *pos += c.len_utf8();
            }
        }
    }
}

fn parse_number(b: &[u8], pos: &mut usize) -> Result<Value, String> {
    let start = *pos;
    if b.get(*pos) == Some(&b'-') {
        *pos += 1;
    }
    let mut float = false;
    while let Some(&c) = b.get(*pos) {
        match c {
            b'0'..=b'9' => *pos += 1,
            b'.' | b'e' | b'E' | b'+' | b'-' => {
                float = true;
                *pos += 1;
            }
            _ => break,
        }
    }
    let text = std::str::from_utf8(&b[start..*pos]).map_err(|e| e.to_string())?;
    if float {
        text.parse::<f64>().map(Value::Float).map_err(|e| format!("number {text:?}: {e}"))
    } else {
        // Integers beyond i64 fall back to f64 rather than failing — the
        // reports never emit them, but a foreign file might.
        match text.parse::<i64>() {
            Ok(n) => Ok(Value::Int(n)),
            Err(_) => {
                text.parse::<f64>().map(Value::Float).map_err(|e| format!("number {text:?}: {e}"))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars_roundtrip() {
        for text in ["null", "true", "false", "0", "-17", "9007199254740993", "\"hi\""] {
            let v = Value::parse(text).unwrap();
            assert_eq!(v.render(), text, "compact render is canonical");
            assert_eq!(Value::parse(&v.render()).unwrap(), v);
        }
        // Large integers stay exact (f64 would corrupt this).
        assert_eq!(Value::parse("9007199254740993").unwrap().as_i64(), Some(9007199254740993));
    }

    #[test]
    fn floats_keep_a_decimal_point() {
        let v = Value::Float(2.0);
        assert_eq!(v.render(), "2.0");
        assert_eq!(Value::parse("2.0").unwrap(), Value::Float(2.0));
        assert_eq!(Value::parse("1e3").unwrap(), Value::Float(1000.0));
        assert_eq!(Value::Float(f64::NAN).render(), "null");
    }

    #[test]
    fn nested_structure_roundtrips() {
        let v = Value::obj(vec![
            ("name", Value::Str("uts-d6".into())),
            ("ok", Value::Bool(true)),
            ("times", Value::Arr(vec![Value::Float(0.5), Value::Float(1.25)])),
            ("nested", Value::obj(vec![("n", Value::Int(42)), ("none", Value::Null)])),
        ]);
        let text = v.render();
        let back = Value::parse(&text).unwrap();
        assert_eq!(back, v);
        assert_eq!(back.get("nested").and_then(|n| n.get("n")).and_then(Value::as_u64), Some(42));
        // Pretty output parses to the same value.
        assert_eq!(Value::parse(&v.render_pretty()).unwrap(), v);
    }

    #[test]
    fn strings_escape_and_unescape() {
        let v = Value::Str("a\"b\\c\nd\te\u{1}".into());
        let text = v.render();
        assert_eq!(Value::parse(&text).unwrap(), v);
        assert!(text.contains("\\u0001"), "{text}");
        assert_eq!(Value::parse("\"\\u0041\"").unwrap(), Value::Str("A".into()));
    }

    #[test]
    fn malformed_documents_are_rejected() {
        for bad in ["", "{", "[1,", "{\"a\":}", "tru", "\"unterminated", "1 2"] {
            assert!(Value::parse(bad).is_err(), "{bad:?} should fail");
        }
        for bad in ["{\"a\":1,\"a\":2}", "{\"a\" 1}", "[1 2]", "\"\\q\""] {
            assert!(Value::parse(bad).is_err(), "{bad:?} should fail");
        }
    }

    #[test]
    fn accessors_are_type_checked() {
        let v = Value::parse("{\"s\":\"x\",\"n\":-3,\"f\":1.5,\"a\":[1]}").unwrap();
        assert_eq!(v.get("s").and_then(Value::as_str), Some("x"));
        assert_eq!(v.get("n").and_then(Value::as_i64), Some(-3));
        assert_eq!(v.get("n").and_then(Value::as_u64), None, "negative is not u64");
        assert_eq!(v.get("f").and_then(Value::as_f64), Some(1.5));
        assert_eq!(v.get("a").and_then(Value::as_arr).map(<[Value]>::len), Some(1));
        assert_eq!(v.get("missing"), None);
        assert_eq!(Value::Null.get("s"), None);
    }

    #[test]
    fn property_random_values_roundtrip() {
        // Seeded structural fuzz: any value the generator builds must
        // survive render → parse unchanged.
        crate::testkit::check_cases("json-roundtrip", 60, |g| {
            fn gen_value(g: &mut crate::testkit::Gen, depth: usize) -> Value {
                // Scalars only at the depth limit.
                let pick = if depth == 0 { g.usize(0..5) } else { g.usize(0..7) };
                match pick {
                    0 => Value::Null,
                    1 => Value::Bool(g.bool(0.5)),
                    2 => Value::Int(g.u64(0..u64::MAX / 4) as i64 - (1i64 << 40)),
                    3 => Value::Float((g.f64() - 0.5) * 1e6),
                    4 => {
                        let len = g.usize(0..8);
                        let alphabet = ['a', '"', '\\', 'é', '\n'];
                        Value::Str((0..len).map(|_| *g.choose(&alphabet)).collect())
                    }
                    5 => {
                        let len = g.usize(0..4);
                        Value::Arr(g.vec(len, |g| gen_value(g, depth - 1)))
                    }
                    _ => {
                        let n = g.usize(0..4);
                        Value::Obj(
                            (0..n).map(|i| (format!("k{i}"), gen_value(g, depth - 1))).collect(),
                        )
                    }
                }
            }
            let v = gen_value(g, 3);
            assert_eq!(Value::parse(&v.render()).unwrap(), v);
            assert_eq!(Value::parse(&v.render_pretty()).unwrap(), v);
        });
    }
}
