//! Small self-contained utilities shared across the library.
//!
//! This crate builds in an offline environment without `rand`, `clap` or
//! `criterion`, so the RNG, statistics helpers and time formatting live
//! here.

pub mod json;
pub mod rng;
pub mod stats;
pub mod timefmt;

pub use rng::SplitMix64;
pub use stats::{mean, percentile, stddev, OnlineStats};
