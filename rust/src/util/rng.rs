//! SplitMix64 PRNG.
//!
//! Used for victim selection, workload generation (R-MAT) and the
//! property-test kit. UTS itself uses the SHA-1 splittable RNG from the
//! benchmark specification (see [`crate::apps::uts::sha1rand`]); SplitMix64
//! is only used where the paper does not pin a generator.
//!
//! Reference: Steele, Lea, Flood — "Fast Splittable Pseudorandom Number
//! Generators", OOPSLA 2014. Constants are the canonical ones.

/// A tiny, fast, seedable, `Copy` PRNG with 64 bits of state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a generator from a seed. Distinct seeds give independent
    /// streams for all practical purposes.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform `u64` in `[0, bound)` (bound > 0) via Lemire's method
    /// without the rejection step — bias is < 2^-64 * bound, irrelevant for
    /// victim selection and workload synthesis.
    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform `f64` in `[0, 1)` using the top 53 bits.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Split off an independent generator (hash the state with a distinct
    /// stream constant).
    pub fn split(&mut self) -> SplitMix64 {
        SplitMix64::new(self.next_u64() ^ 0xA5A5_A5A5_DEAD_BEEF)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

/// One-shot stateless mix of a 64-bit value (the SplitMix64 output
/// function). Used to derive deterministic per-place seeds.
#[inline]
pub fn mix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn known_vector_seed_zero() {
        // First outputs of splitmix64 with seed 0 (cross-checked against the
        // reference C implementation by Vigna).
        let mut g = SplitMix64::new(0);
        assert_eq!(g.next_u64(), 0xE220_A839_7B1D_CDAF);
        assert_eq!(g.next_u64(), 0x6E78_9E6A_A1B9_65F4);
        assert_eq!(g.next_u64(), 0x06C4_5D18_8009_454F);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut g = SplitMix64::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = g.next_below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should appear");
    }

    #[test]
    fn f64_unit_interval() {
        let mut g = SplitMix64::new(3);
        for _ in 0..10_000 {
            let v = g.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn f64_mean_is_half() {
        let mut g = SplitMix64::new(11);
        let n = 100_000;
        let s: f64 = (0..n).map(|_| g.next_f64()).sum();
        let mean = s / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn split_streams_are_distinct() {
        let mut a = SplitMix64::new(9);
        let mut b = a.split();
        let overlap = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(overlap, 0);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut g = SplitMix64::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        g.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>(), "shuffle should move things");
    }
}
