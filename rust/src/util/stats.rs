//! Descriptive statistics used by the logger, the bench harness and the
//! workload-distribution figures (mean / standard deviation per the paper's
//! Figures 6, 8, 10).

/// Arithmetic mean; 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation (the paper reports the spread of the
/// per-place workload, a full population, not a sample).
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Linear-interpolated percentile, `q` in [0, 100].
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (q / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Welford online mean/variance accumulator — used in hot paths (per-chunk
/// timing) where materializing sample vectors would allocate.
#[derive(Debug, Clone, Copy, Default)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl OnlineStats {
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    #[inline]
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }
    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }
    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }
    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Merge another accumulator (parallel Welford / Chan et al.).
    pub fn merge(&mut self, o: &OnlineStats) {
        if o.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = *o;
            return;
        }
        let n = self.n + o.n;
        let d = o.mean - self.mean;
        self.mean += d * o.n as f64 / n as f64;
        self.m2 += o.m2 + d * d * (self.n as f64 * o.n as f64) / n as f64;
        self.n = n;
        self.min = self.min.min(o.min);
        self.max = self.max.max(o.max);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_stddev_basic() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn empty_and_singleton() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(stddev(&[]), 0.0);
        assert_eq!(stddev(&[3.0]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 4.0).abs() < 1e-12);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn online_matches_batch() {
        let xs: Vec<f64> = (0..1000).map(|i| ((i * 37) % 101) as f64).collect();
        let mut o = OnlineStats::new();
        for &x in &xs {
            o.push(x);
        }
        assert!((o.mean() - mean(&xs)).abs() < 1e-9);
        assert!((o.stddev() - stddev(&xs)).abs() < 1e-9);
        assert_eq!(o.count(), 1000);
    }

    #[test]
    fn online_merge_matches_whole() {
        let xs: Vec<f64> = (0..500).map(|i| (i as f64).sin() * 10.0).collect();
        let (a, b) = xs.split_at(123);
        let mut oa = OnlineStats::new();
        let mut ob = OnlineStats::new();
        for &x in a {
            oa.push(x);
        }
        for &x in b {
            ob.push(x);
        }
        oa.merge(&ob);
        let mut whole = OnlineStats::new();
        for &x in &xs {
            whole.push(x);
        }
        assert!((oa.mean() - whole.mean()).abs() < 1e-9);
        assert!((oa.stddev() - whole.stddev()).abs() < 1e-9);
        assert!((oa.min() - whole.min()).abs() < 1e-12);
        assert!((oa.max() - whole.max()).abs() < 1e-12);
    }
}
