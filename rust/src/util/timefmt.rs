//! Human-readable formatting of durations and rates for the CLI and the
//! bench harness output tables.

/// Format nanoseconds adaptively (ns / µs / ms / s).
pub fn fmt_ns(ns: u64) -> String {
    if ns < 1_000 {
        format!("{ns}ns")
    } else if ns < 1_000_000 {
        format!("{:.2}µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2}ms", ns as f64 / 1e6)
    } else {
        format!("{:.3}s", ns as f64 / 1e9)
    }
}

/// Format a rate (events per second) with SI prefixes.
pub fn fmt_rate(per_sec: f64) -> String {
    if per_sec >= 1e9 {
        format!("{:.3}G/s", per_sec / 1e9)
    } else if per_sec >= 1e6 {
        format!("{:.3}M/s", per_sec / 1e6)
    } else if per_sec >= 1e3 {
        format!("{:.3}K/s", per_sec / 1e3)
    } else {
        format!("{per_sec:.1}/s")
    }
}

/// Format a count with thousands separators (`1_234_567`).
pub fn fmt_count(n: u64) -> String {
    let s = n.to_string();
    let mut out = String::with_capacity(s.len() + s.len() / 3);
    for (i, c) in s.chars().enumerate() {
        if i > 0 && (s.len() - i) % 3 == 0 {
            out.push('_');
        }
        out.push(c);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ns_ranges() {
        assert_eq!(fmt_ns(12), "12ns");
        assert_eq!(fmt_ns(1_500), "1.50µs");
        assert_eq!(fmt_ns(2_500_000), "2.50ms");
        assert_eq!(fmt_ns(3_210_000_000), "3.210s");
    }

    #[test]
    fn rates() {
        assert_eq!(fmt_rate(12.0), "12.0/s");
        assert_eq!(fmt_rate(1.5e3), "1.500K/s");
        assert_eq!(fmt_rate(2.25e6), "2.250M/s");
        assert_eq!(fmt_rate(7.5e9), "7.500G/s");
    }

    #[test]
    fn counts() {
        assert_eq!(fmt_count(0), "0");
        assert_eq!(fmt_count(999), "999");
        assert_eq!(fmt_count(1_000), "1_000");
        assert_eq!(fmt_count(1_234_567), "1_234_567");
    }
}
