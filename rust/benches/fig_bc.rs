//! Bench: regenerate the paper's BC figures (Figs 5–10).
//!
//! `cargo bench --bench fig_bc [-- --full]`
//!
//! Figs 5/7/9: BC (static randomized) vs BC-G throughput + efficiency on
//! BGQ / K / Power 775. Figs 6/8/10: the per-place workload distribution
//! (mean and σ) at the sweep's largest place count — the paper's
//! headline BC result is the σ collapse (4.027→1.141 on BGQ,
//! 58.463→1.482 on Power 775).

use glb::glb::GlbParams;
use glb::harness::{fig_bc_perf, fig_bc_workload, FigOpts};
use glb::sim::{ArchProfile, BGQ, K, POWER775};

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    // Per-place source counts matter: the σ collapse (Figs 6/8/10) is a
    // diffusion effect that needs O(100+) sources per place, so the sweep
    // tops out where scale/places keeps that ratio (see EXPERIMENTS.md).
    let (places, scale) = if full {
        (vec![1, 4, 16, 32, 64, 128], 14u32)
    } else {
        (vec![1, 4, 16, 32], 12u32)
    };

    let figs: [(&str, &str, &ArchProfile); 3] = [
        ("Figure 5/6", "Blue Gene/Q", &BGQ),
        ("Figure 7/8", "K", &K),
        ("Figure 9/10", "Power 775", &POWER775),
    ];
    for (tag, name, arch) in figs {
        let opts = FigOpts {
            places: places.clone(),
            uts_depth: 0,
            bc_scale: scale,
            // §2.6: BC-G uses the interruptible state machine with a
            // sub-vertex edge budget per chunk, and maximized w (the
            // paper: "maximize w and z and minimize n").
            params: GlbParams::default().with_n(8192).with_w(4).with_l(2),
            csv: false,
        };
        println!("=== {tag}a: BC/BC-G performance on {name} ===");
        let f = fig_bc_perf(arch, &opts);
        print!("{}", f.text);
        let (l, g) = (f.legacy.last().unwrap(), f.glb.last().unwrap());
        println!(
            "[{tag}a] at {} places: BC-G eff={:.3} vs BC eff={:.3} (BC-G/BC rate={:.2})",
            g.places,
            g.efficiency,
            l.efficiency,
            g.rate / l.rate.max(1e-9)
        );

        println!("\n=== {tag}b: BC/BC-G workload distribution on {name} ===");
        let (_table, summary) = fig_bc_workload(arch, &opts);
        println!("{summary}\n");
    }
}
