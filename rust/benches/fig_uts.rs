//! Bench: regenerate the paper's UTS figures (Figs 2, 3, 4).
//!
//! `cargo bench --bench fig_uts [-- --full]`
//!
//! For each architecture (Power 775 ≤256, BGQ and K to larger sweeps),
//! prints the legacy-UTS vs UTS-G throughput and efficiency series. The
//! default sweep is sized for minutes on one core; `--full` pushes the
//! BGQ/K sweeps to the paper's 8K/16K place counts (slower).

use glb::glb::GlbParams;
use glb::harness::{fig_uts, FigOpts};
use glb::sim::{BGQ, K, POWER775};

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    // Depth scales with the sweep (the paper varies d 13–20 by core
    // count for the same reason: keep per-place work meaningful).
    // Base depth 9 keeps the default run under a minute per figure with
    // efficiency ~0.8 at the top of the sweep; the paper's near-1.0
    // plateau needs its minutes-long per-place workloads, which is what
    // --full approaches (and ablation 6 in `cargo bench --bench ablation`
    // demonstrates the convergence on one point). Depth scales with p as
    // d(p) = base + ceil(log4 p), mirroring the paper's d = 13..20.
    let (places_small, places_big, depth) = if full {
        (vec![1, 4, 16, 64, 256, 1024], vec![1, 4, 16, 64, 256, 1024, 4096], 10)
    } else {
        (vec![1, 4, 16, 64, 256], vec![1, 4, 16, 64, 256], 9)
    };

    let opts = |places: Vec<usize>| FigOpts {
        places,
        uts_depth: depth,
        bc_scale: 0,
        params: GlbParams::default(),
        csv: false,
    };

    println!("=== Figure 2: UTS/UTS-G on Power 775 (paper: ≤256 places) ===");
    let f2 = fig_uts(&POWER775, &opts(places_small.clone()));
    print!("{}", f2.text);
    summarize("fig2", &f2);

    println!("\n=== Figure 3: UTS/UTS-G on Blue Gene/Q (paper: ≤16384 places) ===");
    let f3 = fig_uts(&BGQ, &opts(places_big.clone()));
    print!("{}", f3.text);
    summarize("fig3", &f3);

    println!("\n=== Figure 4: UTS/UTS-G on K (paper: ≤8192, droop past 4096) ===");
    let f4 = fig_uts(&K, &opts(places_big));
    print!("{}", f4.text);
    summarize("fig4", &f4);
}

fn summarize(tag: &str, f: &glb::harness::figures::Figure) {
    let last = f.glb.last().unwrap();
    let legacy_last = f.legacy.last().unwrap();
    println!(
        "[{tag}] at {} places: UTS-G eff={:.3}, legacy eff={:.3}, UTS-G/legacy rate ratio={:.2}",
        last.places,
        last.efficiency,
        legacy_last.efficiency,
        last.rate / legacy_last.rate.max(1e-9)
    );
}
