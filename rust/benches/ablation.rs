//! Ablation benches for the design choices DESIGN.md calls out
//! (paper §2.4's tuning discussion + §5's comparison to random-only
//! stealing):
//!
//!  1. lifeline vs random-only stealing across place counts;
//!  2. task granularity `n` sweep (the §2.6 responsiveness trade-off);
//!  3. random-victim budget `w` sweep;
//!  4. lifeline arity `l` (hypercube shape) sweep;
//!  5. GLB vs naive static partitioning of UTS (§2.5.1);
//!  6. efficiency vs per-place work;
//!  7. flat vs hierarchical topology (cross-node messages per work unit).
//!
//! `cargo bench --bench ablation`

use glb::apps::uts::{UtsParams, UtsQueue};
use glb::baselines::legacy_uts::random_only_params;
use glb::baselines::static_uts::run_static_uts_sim;
use glb::glb::task_queue::SumReducer;
use glb::glb::{GlbConfig, GlbParams, RunOutput};
use glb::harness::{calibrate_uts_cost, Table};
use glb::sim::{run_sim, CostModel, SimReport, BGQ};

fn uts_run(p: usize, params: GlbParams, depth: u32, cost: CostModel) -> (RunOutput<u64>, SimReport) {
    let up = UtsParams { b0: 4.0, seed: 19, max_depth: depth };
    let cfg = GlbConfig::new(p, params);
    run_sim(&cfg, &BGQ, cost, |_, _| UtsQueue::new(up), |q| q.init_root(), &SumReducer)
}

fn uts_rate(p: usize, params: GlbParams, depth: u32, cost: CostModel) -> (f64, u64) {
    let (out, rep) = uts_run(p, params, depth, cost);
    (out.units_per_sec(), rep.messages)
}

fn main() {
    let cost = calibrate_uts_cost();
    let depth = 9;

    println!("=== Ablation 1: lifeline vs random-only stealing (UTS d={depth}, BGQ) ===");
    let mut t = Table::new(&["places", "lifeline nodes/s", "random-only nodes/s", "lifeline advantage"]);
    for p in [16usize, 64, 256, 1024] {
        let (lf, _) = uts_rate(p, GlbParams::default(), depth, cost);
        let (ro, _) = uts_rate(p, random_only_params(1, 2), depth, cost);
        t.row(&[
            p.to_string(),
            format!("{lf:.3e}"),
            format!("{ro:.3e}"),
            format!("{:.2}x", lf / ro.max(1e-9)),
        ]);
    }
    print!("{}", t.render());

    println!("\n=== Ablation 2: task granularity n (paper §2.4) ===");
    let mut t = Table::new(&["n", "nodes/s (p=256)", "messages"]);
    for n in [1usize, 15, 127, 511, 4095, 32767] {
        let (rate, msgs) = uts_rate(256, GlbParams::default().with_n(n), depth, cost);
        t.row(&[n.to_string(), format!("{rate:.3e}"), msgs.to_string()]);
    }
    print!("{}", t.render());

    println!("\n=== Ablation 3: random-victim budget w ===");
    let mut t = Table::new(&["w", "nodes/s (p=256)", "messages"]);
    for w in [0usize, 1, 2, 4, 8] {
        let (rate, msgs) = uts_rate(256, GlbParams::default().with_w(w), depth, cost);
        t.row(&[w.to_string(), format!("{rate:.3e}"), msgs.to_string()]);
    }
    print!("{}", t.render());

    println!("\n=== Ablation 4: lifeline arity l (cube shape) ===");
    let mut t = Table::new(&["l", "z(derived)", "nodes/s (p=256)"]);
    for l in [2usize, 4, 16, 32] {
        let params = GlbParams::default().with_l(l);
        let (rate, _) = uts_rate(256, params, depth, cost);
        t.row(&[l.to_string(), params.resolve_z(256).to_string(), format!("{rate:.3e}")]);
    }
    print!("{}", t.render());

    println!("\n=== Ablation 6: efficiency vs per-place work (why the paper's long runs sit at ~1.0) ===");
    let mut t = Table::new(&["depth", "nodes", "eff at p=256"]);
    for d in [12u32, 13, 14] {
        let up = UtsParams { b0: 4.0, seed: 19, max_depth: d };
        let cfg = GlbConfig::new(256, GlbParams::default());
        let (out, _) = run_sim(&cfg, &BGQ, cost, |_, _| UtsQueue::new(up), |q| q.init_root(), &SumReducer);
        let ideal = out.result as f64 / 256.0 * cost.ns_per_unit / BGQ.compute_scale;
        t.row(&[d.to_string(), out.result.to_string(), format!("{:.3}", ideal / out.elapsed_ns as f64)]);
    }
    print!("{}", t.render());

    println!("\n=== Ablation 5: GLB vs naive static UTS partitioning (§2.5.1) ===");
    let mut t = Table::new(&["places", "GLB makespan (ms)", "static makespan (ms)", "static penalty"]);
    let up = UtsParams { b0: 4.0, seed: 19, max_depth: depth };
    for p in [4usize, 16, 64] {
        let cfg = GlbConfig::new(p, GlbParams::default());
        let (out, _) = run_sim(&cfg, &BGQ, cost, |_, _| UtsQueue::new(up), |q| q.init_root(), &SumReducer);
        let st = run_static_uts_sim(&up, p, cost.ns_per_unit / BGQ.compute_scale);
        t.row(&[
            p.to_string(),
            format!("{:.2}", out.elapsed_ns as f64 / 1e6),
            format!("{:.2}", st.elapsed_ns as f64 / 1e6),
            format!("{:.2}x", st.elapsed_ns as f64 / out.elapsed_ns as f64),
        ]);
    }
    print!("{}", t.render());

    println!("\n=== Ablation 7: flat vs hierarchical topology (equal workers, BGQ 16 places/node) ===");
    let mut t = Table::new(&[
        "workers",
        "wpn",
        "nodes/s",
        "cross msgs",
        "cross msgs / Mnode",
        "total msgs",
    ]);
    for &(workers, wpn) in
        &[(64usize, 1usize), (64, 16), (256, 1), (256, 16), (1024, 1), (1024, 16)]
    {
        let params = GlbParams::default().with_n(64).with_workers_per_node(wpn);
        let (out, rep) = uts_run(workers, params, depth, cost);
        let per_mnode = rep.cross_messages as f64 * 1e6 / out.result as f64;
        t.row(&[
            workers.to_string(),
            wpn.to_string(),
            format!("{:.3e}", out.units_per_sec()),
            rep.cross_messages.to_string(),
            format!("{per_mnode:.1}"),
            rep.messages.to_string(),
        ]);
    }
    print!("{}", t.render());
    println!(
        "(wpn=16 builds the lifeline cube over nodes and shares locally through the node bag: \
         same tree count, far fewer NIC-charged messages per unit of work)"
    );
}
