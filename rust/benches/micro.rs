//! Microbenchmarks of the GLB hot paths (the §Perf baseline numbers).
//!
//! `cargo bench --bench micro`
//!
//! Measures, with repeat-and-best-of timing:
//!  * UTS node expansion rate (SHA-1 bound — the sequential compute rate
//!    everything else is normalized by);
//!  * sparse Brandes edge rate;
//!  * task-bag split/merge costs at several sizes;
//!  * thread-runtime steal round-trip latency (2 places);
//!  * simulator event throughput;
//!  * PJRT batched-Brandes call latency (if artifacts exist).

use std::sync::Arc;
use std::time::Instant;

use glb::apps::bc::{brandes_source, BrandesScratch, Graph, RmatParams};
use glb::apps::uts::{UtsBag, UtsParams, UtsQueue, UtsTree};
use glb::glb::task_bag::{ArrayListTaskBag, TaskBag};
use glb::glb::task_queue::SumReducer;
use glb::glb::{GlbConfig, GlbParams};
use glb::harness::Table;
use glb::place::run_threads;
use glb::sim::{run_sim, CostModel, BGQ};
use glb::util::timefmt::{fmt_ns, fmt_rate};

/// Best-of-k wall time of `f`, in ns.
fn best_of<F: FnMut() -> u64>(k: usize, mut f: F) -> (u64, u64) {
    let mut best = u64::MAX;
    let mut units = 0;
    for _ in 0..k {
        let t = Instant::now();
        units = f();
        best = best.min(t.elapsed().as_nanos() as u64);
    }
    (best, units)
}

fn main() {
    let mut t = Table::new(&["benchmark", "time", "rate"]);

    // UTS expansion.
    let up = UtsParams { b0: 4.0, seed: 19, max_depth: 9 };
    let tree = UtsTree::new(up);
    let (ns, nodes) = best_of(3, || {
        let mut bag = UtsBag::with_root(&tree);
        let mut c = 1u64;
        loop {
            let (k, more) = bag.expand_some(&tree, 1 << 16);
            c += k;
            if !more {
                break c;
            }
        }
    });
    t.row(&[
        format!("uts expand d=9 ({nodes} nodes)"),
        fmt_ns(ns),
        fmt_rate(nodes as f64 * 1e9 / ns as f64) + " nodes/s",
    ]);

    // Sparse Brandes.
    let g = Graph::rmat(RmatParams { scale: 11, ..Default::default() });
    let (ns, edges) = best_of(3, || {
        let mut bc = vec![0.0; g.n()];
        let mut sc = BrandesScratch::new(g.n());
        let mut e = 0u64;
        for s in 0..256u32 {
            e += brandes_source(&g, s, &mut bc, &mut sc);
        }
        e
    });
    t.row(&[
        format!("brandes 256 sources scale-11"),
        fmt_ns(ns),
        fmt_rate(edges as f64 * 1e9 / ns as f64) + " edges/s",
    ]);

    // Bag split/merge.
    for size in [64usize, 4096, 262144] {
        let (ns, _) = best_of(5, || {
            let mut bag = ArrayListTaskBag::from_vec((0..size as u64).collect());
            let mut n = 0u64;
            while let Some(loot) = bag.split() {
                n += 1;
                if bag.size() < 2 {
                    bag.merge(loot);
                    break;
                }
                std::mem::drop(loot);
            }
            n
        });
        t.row(&[format!("bag split-to-exhaustion ({size})"), fmt_ns(ns), "-".into()]);
    }

    // Steal round-trip over threads: 2 places, 1 task each chunk forces
    // constant starvation -> measures protocol overhead.
    let (ns, chunks) = best_of(3, || {
        let cfg = GlbConfig::new(2, GlbParams::default().with_n(1).with_l(2));
        let out = run_threads(
            &cfg,
            |_, _| UtsQueue::new(UtsParams { b0: 4.0, seed: 19, max_depth: 5 }),
            |q| q.init_root(),
            &SumReducer,
        );
        out.log.total().chunks
    });
    t.row(&[
        format!("thread runtime n=1 churn ({chunks} chunks)"),
        fmt_ns(ns),
        fmt_rate(chunks as f64 * 1e9 / ns as f64) + " chunks/s",
    ]);

    // Simulator event rate.
    let (ns, events) = best_of(3, || {
        let cfg = GlbConfig::new(256, GlbParams::default().with_n(64));
        let (_, rep) = run_sim(
            &cfg,
            &BGQ,
            CostModel::new(200.0, 60, 32),
            |_, _| UtsQueue::new(UtsParams { b0: 4.0, seed: 19, max_depth: 8 }),
            |q| q.init_root(),
            &SumReducer,
        );
        rep.events
    });
    t.row(&[
        format!("sim 256 places d=8 ({events} events)"),
        fmt_ns(ns),
        fmt_rate(events as f64 * 1e9 / ns as f64) + " events/s",
    ]);

    // PJRT call latency (needs artifacts).
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.txt").exists() {
        let gg = Arc::new(Graph::rmat(RmatParams { scale: 8, ..Default::default() }));
        let mut eng = glb::runtime::Engine::new(&dir).unwrap();
        let be = eng.brandes(&gg.dense_adjacency(), gg.n()).unwrap();
        let sources: Vec<u32> = (0..be.s as u32).collect();
        eng.run_brandes(&be, &sources).unwrap(); // warm the compile cache
        let (ns, edges) = best_of(5, || eng.run_brandes(&be, &sources).unwrap().edges);
        t.row(&[
            format!("pjrt brandes n={} S={}", be.n, be.s),
            fmt_ns(ns),
            fmt_rate(edges as f64 * 1e9 / ns as f64) + " edges/s",
        ]);
    } else {
        eprintln!("(skipping pjrt bench: run `make artifacts`)");
    }

    print!("{}", t.render());
}
