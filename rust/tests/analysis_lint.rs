//! Tests for `glb lint` itself: seeded fixture snippets that must each
//! produce exactly one finding per rule family, and the self-policing
//! tier-1 gate — the real source tree must lint clean.
//!
//! Fixtures impersonate real tree paths (`glb/wire.rs`,
//! `rust/tests/properties.rs`, `place/reactor.rs`) because rule
//! applicability is decided by path suffix.

use glb::analysis::{lint_sources, lint_tree, render, Rule, SourceFile};

fn src(path: &str, text: &str) -> SourceFile {
    SourceFile { path: path.to_string(), text: text.to_string() }
}

/// A minimal wire registry: full Msg family, two Ctrl tags.
const WIRE_FIXTURE: &str = "
pub const TAG_STEAL: u8 = 0;
pub const TAG_LOOT: u8 = 1;
pub const TAG_TERMINATE: u8 = 2;
const CTRL_REGISTER: u8 = 0;
const CTRL_GO: u8 = 1;
";

/// A properties.rs fixture exercising both fixture variants through
/// every coverage family; tests cut pieces out of it to seed findings.
fn props_fixture(omit_fn: &str) -> String {
    let all = [
        (
            "prop_wire_roundtrip_every_msg_variant_uts",
            "let _ = (Msg::Steal { thief: 0, nonce: 1 }, Msg::Loot, Msg::Terminate);",
        ),
        ("prop_ctrl_roundtrip_every_variant", "for v in 0..CTRL_VARIANTS { gen(v); }"),
        ("prop_wire_truncated_frames_error_not_panic", "cut_frames();"),
        ("prop_frame_assembler_decodes_any_split_points", "split_points();"),
        ("prop_ctrl_hostile_bytes_error_not_panic", "for v in 0..CTRL_VARIANTS { gen(v); }"),
        (
            "prop_pooled_encode_matches_allocating_encode_byte_for_byte",
            "for v in 0..CTRL_VARIANTS { gen(v); }",
        ),
    ];
    let mut out = String::from(
        "const CTRL_VARIANTS: usize = 2;\n\
         fn gen(v: usize) { match v { 0 => use_ctrl(Ctrl::Register), _ => use_ctrl(Ctrl::Go) } }\n",
    );
    for (name, body) in all {
        if name == omit_fn {
            continue;
        }
        out.push_str(&format!("fn {name}() {{ {body} }}\n"));
    }
    out
}

// ---------------------------------------------------------------------
// rule family 1: wire-tag registry
// ---------------------------------------------------------------------

#[test]
fn wire_tag_missing_truncation_coverage_is_one_finding() {
    let files = [
        src("rust/src/glb/wire.rs", WIRE_FIXTURE),
        src(
            "rust/tests/properties.rs",
            &props_fixture("prop_wire_truncated_frames_error_not_panic"),
        ),
    ];
    let findings = lint_sources(&files);
    assert_eq!(findings.len(), 1, "unexpected findings:\n{}", render(&findings));
    assert_eq!(findings[0].rule, Rule::WireRegistry);
    assert!(
        findings[0].message.contains("truncation"),
        "finding must name the missing family: {}",
        findings[0].message
    );
}

#[test]
fn complete_wire_coverage_lints_clean() {
    let files = [
        src("rust/src/glb/wire.rs", WIRE_FIXTURE),
        src("rust/tests/properties.rs", &props_fixture("")),
    ];
    let findings = lint_sources(&files);
    assert!(findings.is_empty(), "expected clean:\n{}", render(&findings));
}

// ---- rule 2: wire-protocol doc cross-check -------------------------

/// A spec fixture documenting every tag in [`WIRE_FIXTURE`], plus the
/// `CTRL_VARIANTS` pin (exempt from the stale-tag direction).
const DOC_FIXTURE: &str = "# Wire protocol\n\n\
Msg tags: TAG_STEAL, TAG_LOOT, TAG_TERMINATE.\n\
Ctrl tags: CTRL_REGISTER, CTRL_GO.\n\
The property suite pins the registry size via CTRL_VARIANTS.\n";

#[test]
fn documented_registry_lints_clean() {
    let files = [
        src("rust/src/glb/wire.rs", WIRE_FIXTURE),
        src("rust/tests/properties.rs", &props_fixture("")),
        src("docs/wire-protocol.md", DOC_FIXTURE),
    ];
    let findings = lint_sources(&files);
    assert!(findings.is_empty(), "expected clean:\n{}", render(&findings));
}

#[test]
fn undocumented_wire_tag_is_one_finding() {
    let doc = DOC_FIXTURE.replace(", TAG_TERMINATE", "");
    let files = [
        src("rust/src/glb/wire.rs", WIRE_FIXTURE),
        src("rust/tests/properties.rs", &props_fixture("")),
        src("docs/wire-protocol.md", &doc),
    ];
    let findings = lint_sources(&files);
    assert_eq!(findings.len(), 1, "unexpected findings:\n{}", render(&findings));
    assert_eq!(findings[0].rule, Rule::WireDoc);
    assert_eq!(findings[0].path, "rust/src/glb/wire.rs");
    assert!(
        findings[0].message.contains("TAG_TERMINATE"),
        "finding must name the undocumented tag: {}",
        findings[0].message
    );
}

#[test]
fn stale_doc_tag_is_one_finding() {
    let doc = format!("{DOC_FIXTURE}Retired: CTRL_HANDSHAKE2 framing.\n");
    let files = [
        src("rust/src/glb/wire.rs", WIRE_FIXTURE),
        src("rust/tests/properties.rs", &props_fixture("")),
        src("docs/wire-protocol.md", &doc),
    ];
    let findings = lint_sources(&files);
    assert_eq!(findings.len(), 1, "unexpected findings:\n{}", render(&findings));
    assert_eq!(findings[0].rule, Rule::WireDoc);
    assert_eq!(findings[0].path, "docs/wire-protocol.md");
    assert_eq!(findings[0].line, 6, "stale tag sits on the appended line");
    assert!(
        findings[0].message.contains("CTRL_HANDSHAKE2"),
        "finding must name the stale tag: {}",
        findings[0].message
    );
}

#[test]
fn missing_protocol_doc_fails_the_tree_lint() {
    // A tree with a wire registry but no docs/wire-protocol.md: the
    // tree walk itself reports the absent spec.
    let dir = std::path::Path::new(env!("CARGO_TARGET_TMPDIR")).join("glb-wire-doc-fixture");
    let glb_dir = dir.join("rust/src/glb");
    std::fs::create_dir_all(&glb_dir).expect("mk fixture tree");
    std::fs::write(glb_dir.join("wire.rs"), WIRE_FIXTURE).expect("write wire fixture");
    std::fs::create_dir_all(dir.join("rust/tests")).expect("mk tests dir");
    std::fs::write(dir.join("rust/tests/properties.rs"), props_fixture(""))
        .expect("write props fixture");
    let findings = lint_tree(&dir).expect("lint walks the fixture tree");
    std::fs::remove_dir_all(&dir).ok();
    assert_eq!(findings.len(), 1, "unexpected findings:\n{}", render(&findings));
    assert_eq!(findings[0].rule, Rule::WireDoc);
    assert_eq!(findings[0].path, "docs/wire-protocol.md");
    assert!(findings[0].message.contains("missing protocol spec"));
}

#[test]
fn new_ctrl_tag_without_property_coverage_fails() {
    // A PR adds CTRL_SUBMIT but forgets the property suite entirely:
    // the variant-count pin and the generator reference both fire.
    let wire = format!("{WIRE_FIXTURE}const CTRL_SUBMIT: u8 = 2;\n");
    let files = [
        src("rust/src/glb/wire.rs", &wire),
        src("rust/tests/properties.rs", &props_fixture("")),
    ];
    let findings = lint_sources(&files);
    assert!(
        findings.iter().any(|f| f.message.contains("CTRL_VARIANTS")),
        "variant-count pin must fire:\n{}",
        render(&findings)
    );
    assert!(
        findings.iter().any(|f| f.message.contains("Ctrl::Submit")),
        "generator reference must fire:\n{}",
        render(&findings)
    );
}

#[test]
fn duplicate_and_sparse_tags_are_findings() {
    let wire = "
const CTRL_REGISTER: u8 = 0;
const CTRL_GO: u8 = 0;
const CTRL_LATE: u8 = 7;
";
    let files = [
        src("rust/src/glb/wire.rs", wire),
        src(
            "rust/tests/properties.rs",
            "const CTRL_VARIANTS: usize = 3;\n\
             fn g() { (Ctrl::Register, Ctrl::Go, Ctrl::Late); }\n",
        ),
    ];
    let findings = lint_sources(&files);
    assert!(
        findings.iter().any(|f| f.message.contains("reuses wire value")),
        "duplicate must fire:\n{}",
        render(&findings)
    );
    assert!(
        findings.iter().any(|f| f.message.contains("not dense")),
        "density must fire:\n{}",
        render(&findings)
    );
}

// ---------------------------------------------------------------------
// rule family 2: unsafe audit
// ---------------------------------------------------------------------

#[test]
fn unsafe_without_safety_comment_is_one_finding() {
    let files = [src(
        "rust/src/place/fixture.rs",
        "fn open() -> i32 {\n    unsafe { raw_open() }\n}\n",
    )];
    let findings = lint_sources(&files);
    assert_eq!(findings.len(), 1, "unexpected findings:\n{}", render(&findings));
    assert_eq!(findings[0].rule, Rule::UnsafeSafety);
    assert_eq!(findings[0].line, 2);
}

#[test]
fn unsafe_with_safety_comment_lints_clean() {
    let files = [src(
        "rust/src/place/fixture.rs",
        "fn open() -> i32 {\n    // SAFETY: raw_open takes no pointers.\n    unsafe { raw_open() }\n}\n",
    )];
    assert!(lint_sources(&files).is_empty());
}

// ---------------------------------------------------------------------
// rule family 3: atomic-ordering allowlist
// ---------------------------------------------------------------------

#[test]
fn disallowed_relaxed_is_one_finding() {
    let files = [src(
        "rust/src/place/fixture.rs",
        "fn f(flag: &AtomicBool) {\n    flag.store(true, Ordering::Relaxed);\n}\n",
    )];
    let findings = lint_sources(&files);
    assert_eq!(findings.len(), 1, "unexpected findings:\n{}", render(&findings));
    assert_eq!(findings[0].rule, Rule::AtomicOrdering);
    assert_eq!(findings[0].line, 2);
}

#[test]
fn allowlisted_relaxed_symbol_lints_clean() {
    // spurious_wakeups in place/network.rs is a declared counter site,
    // even when the call spans lines (statement-span matching).
    let files = [src(
        "rust/src/place/network.rs",
        "fn f() {\n    spurious_wakeups.fetch_add(\n        1,\n        Ordering::Relaxed,\n    );\n}\n",
    )];
    assert!(lint_sources(&files).is_empty());
}

// ---------------------------------------------------------------------
// rule family 4: hot-path panic lint
// ---------------------------------------------------------------------

/// A reactor fixture defining every declared hot fn; `flush` carries
/// the seeded violation.
const REACTOR_FIXTURE: &str = "
impl Backend {
    fn wait(&self) {}
    fn push(&self) {}
    fn flush(&self) {
        self.inner.lock().unwrap();
    }
    fn wake(&self) {}
    fn drain(&self) {}
}
fn setup_only() {
    spawn().expect(\"one-time setup may panic\");
}
#[cfg(test)]
mod tests {
    fn helper() {
        q.flush().unwrap();
    }
}
";

#[test]
fn unwrap_in_hot_region_is_one_finding() {
    let files = [src("rust/src/place/reactor.rs", REACTOR_FIXTURE)];
    let findings = lint_sources(&files);
    assert_eq!(findings.len(), 1, "unexpected findings:\n{}", render(&findings));
    assert_eq!(findings[0].rule, Rule::HotPathPanic);
    assert!(findings[0].message.contains("fn flush"));
    assert_eq!(findings[0].line, 6);
}

#[test]
fn renamed_hot_fn_is_itself_a_finding() {
    // Dropping a declared fn (say `wake`) must not silently shrink the
    // lint's coverage.
    let fixture = REACTOR_FIXTURE.replace("fn wake", "fn wake_renamed").replace(
        "self.inner.lock().unwrap();",
        "let _ = self.inner.lock();",
    );
    let files = [src("rust/src/place/reactor.rs", &fixture)];
    let findings = lint_sources(&files);
    assert_eq!(findings.len(), 1, "unexpected findings:\n{}", render(&findings));
    assert!(findings[0].message.contains("fn wake"));
}

// ---------------------------------------------------------------------
// the self-policing gate + CLI surface
// ---------------------------------------------------------------------

#[test]
fn repo_tree_lints_clean() {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    let findings = lint_tree(root).expect("lint walks the repo tree");
    assert!(
        findings.is_empty(),
        "the source tree must satisfy its own invariants:\n{}",
        render(&findings)
    );
}

#[test]
fn render_reports_counts_per_rule() {
    let files = [src(
        "rust/src/place/fixture.rs",
        "fn f(flag: &AtomicBool) {\n    flag.store(true, Ordering::Relaxed);\n    unsafe { raw() };\n}\n",
    )];
    let findings = lint_sources(&files);
    let text = render(&findings);
    assert!(text.contains("2 finding(s)"), "summary line: {text}");
    assert!(text.contains("unsafe-safety") && text.contains("atomic-ordering"));
    assert!(render(&[]).contains("clean"));
}

#[test]
fn lint_cli_exits_nonzero_on_violations_and_zero_on_the_tree() {
    let bin = env!("CARGO_BIN_EXE_glb");
    let root = env!("CARGO_MANIFEST_DIR");

    let ok = std::process::Command::new(bin)
        .args(["lint", "--root", root])
        .output()
        .expect("run glb lint");
    assert!(
        ok.status.success(),
        "glb lint must exit zero on the repo tree:\n{}",
        String::from_utf8_lossy(&ok.stdout)
    );
    assert!(String::from_utf8_lossy(&ok.stdout).contains("clean"));

    // A tree with a seeded violation: nonzero exit, finding on stdout.
    let dir = std::path::Path::new(env!("CARGO_TARGET_TMPDIR")).join("glb-lint-fixture");
    let src_dir = dir.join("rust/src");
    std::fs::create_dir_all(&src_dir).expect("mk fixture tree");
    std::fs::write(src_dir.join("bad.rs"), "fn f() { unsafe { raw() } }\n")
        .expect("write fixture");
    let bad = std::process::Command::new(bin)
        .args(["lint", "--root", dir.to_str().expect("utf8 temp path")])
        .output()
        .expect("run glb lint");
    assert!(!bad.status.success(), "seeded violation must fail the lint");
    assert!(String::from_utf8_lossy(&bad.stdout).contains("unsafe-safety"));
    std::fs::remove_dir_all(&dir).ok();
}
