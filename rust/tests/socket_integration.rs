//! Multi-process socket-transport integration tests.
//!
//! These tests exercise the real thing: N OS processes (children of this
//! test binary, via `testkit::fleet`) running the GLB lifeline protocol
//! over localhost TCP — direct spoke-to-spoke mesh links, credit-based
//! distributed termination, and rank 0 reduced to bootstrap/discovery.
//! The summed fleet result must be bit-identical to the single-process
//! thread runtime at the same worker count — UTS counts a deterministic
//! tree, so any protocol bug (lost loot, lost credit, double-merge,
//! premature terminate) shows up as a count mismatch or a hang (caught
//! by the fleet watchdog).
//!
//! The harness always splits bind from advertise (rank 0 binds `0.0.0.0`
//! while the fleet dials `127.0.0.1`), so every fleet test doubles as a
//! regression test for the rank-0 bind/advertise fix.
//!
//! Children re-enter the *same test function* with `--exact`; the
//! `fleet::child_role()` check at the top of each test routes them to
//! the child body. CI runs this file with `--test-threads=1` (each
//! orchestrator spawns a process fleet; see .github/workflows/ci.yml).

use std::time::Duration;

use glb::apps::uts::{sequential_count, UtsParams, UtsQueue};
use glb::glb::task_queue::SumReducer;
use glb::glb::{GlbConfig, GlbParams};
use glb::place::{misrouted_frames, run_sockets, run_threads, SocketRunOpts};
use glb::testkit::fleet;

const DEPTH: u32 = 7;
const FLEET_DEADLINE: Duration = Duration::from_secs(120);

fn up() -> UtsParams {
    UtsParams { b0: 4.0, seed: 19, max_depth: DEPTH }
}

fn params() -> GlbParams {
    GlbParams::default().with_n(64).with_l(2)
}

/// Fleet-child body: run this rank's share of the UTS computation and
/// report the local counters on stdout.
fn run_child(role: fleet::ChildRole, params: GlbParams, p: usize) {
    let cfg = GlbConfig::new(p, params);
    let opts = SocketRunOpts {
        rank: role.rank,
        ranks: role.ranks,
        port: role.port,
        host: role.host.clone(),
        bind: role.bind.clone(),
        ..Default::default()
    };
    let out =
        run_sockets(&cfg, &opts, |_, _| UtsQueue::new(up()), |q| q.init_root(), &SumReducer)
            .expect("fleet child run failed");
    let t = out.log.total();
    fleet::emit(
        role.rank,
        &[
            ("result", out.result.to_string()),
            ("places", out.log.per_place.len().to_string()),
            ("loot_sent", t.loot_bags_sent.to_string()),
            ("loot_recv", t.loot_bags_received.to_string()),
            ("steals_recv", (t.random_steals_received + t.lifeline_steals_received).to_string()),
            ("node_donations", t.node_donations.to_string()),
            ("node_takes", t.node_takes.to_string()),
            // Frames this rank received for places it does not host —
            // star-style relay traffic, which the mesh must never carry.
            ("relayed", misrouted_frames().to_string()),
        ],
    );
}

#[test]
#[ignore = "process fleet: run explicitly via `--ignored --test-threads=1` (see CI)"]
fn four_process_uts_fleet_matches_thread_runtime() {
    if let Some(role) = fleet::child_role() {
        run_child(role, params(), 4);
        return;
    }
    let port = fleet::free_port();
    let logs =
        fleet::run("four_process_uts_fleet_matches_thread_runtime", 4, port, FLEET_DEADLINE);
    assert_eq!(logs.len(), 4);
    for l in &logs {
        assert_eq!(l.u64("places"), 1, "flat fleet: one worker per process");
    }

    // The acceptance bar: a 4-process TCP fleet produces results
    // bit-identical to the thread runtime at equal worker count.
    let fleet_total: u64 = logs.iter().map(|l| l.u64("result")).sum();
    let cfg = GlbConfig::new(4, params());
    let reference = run_threads(&cfg, |_, _| UtsQueue::new(up()), |q| q.init_root(), &SumReducer);
    assert_eq!(fleet_total, reference.result, "fleet must count the exact same tree");
    assert_eq!(fleet_total, sequential_count(&up()), "and the tree is the sequential one");

    // Conservation across the wire: every loot bag sent over TCP landed.
    let sent: u64 = logs.iter().map(|l| l.u64("loot_sent")).sum();
    let recv: u64 = logs.iter().map(|l| l.u64("loot_recv")).sum();
    assert_eq!(sent, recv, "loot conservation over TCP");
    assert!(recv > 0, "a 4-process UTS run must actually move work");
}

#[test]
#[ignore = "process fleet: run explicitly via `--ignored --test-threads=1` (see CI)"]
fn mesh_fleet_bit_identical_and_rank0_relays_nothing() {
    // The tentpole acceptance test: after the start barrier no cross-rank
    // steal/loot/refusal frame transits rank 0 — every rank (rank 0
    // included) sees only frames addressed to its own places — while the
    // 4-process mesh stays bit-identical to the thread runtime at equal
    // worker count.
    if let Some(role) = fleet::child_role() {
        run_child(role, params(), 4);
        return;
    }
    let port = fleet::free_port();
    let logs =
        fleet::run("mesh_fleet_bit_identical_and_rank0_relays_nothing", 4, port, FLEET_DEADLINE);
    assert_eq!(logs.len(), 4);
    for l in &logs {
        assert_eq!(
            l.u64("relayed"),
            0,
            "rank {} received frames for places it does not host (star relay!)",
            l.rank
        );
    }
    // Steal traffic reached the spokes directly: with one place per rank,
    // any steal a spoke answers arrived on a direct mesh link.
    let spoke_steals: u64 = logs.iter().skip(1).map(|l| l.u64("steals_recv")).sum();
    assert!(spoke_steals > 0, "spokes must be stolen from over the mesh");

    let fleet_total: u64 = logs.iter().map(|l| l.u64("result")).sum();
    let cfg = GlbConfig::new(4, params());
    let reference = run_threads(&cfg, |_, _| UtsQueue::new(up()), |q| q.init_root(), &SumReducer);
    assert_eq!(fleet_total, reference.result, "mesh fleet bit-identical to thread runtime");
    assert_eq!(fleet_total, sequential_count(&up()));
    let sent: u64 = logs.iter().map(|l| l.u64("loot_sent")).sum();
    let recv: u64 = logs.iter().map(|l| l.u64("loot_recv")).sum();
    assert_eq!(sent, recv, "loot (and its credit) conserved over the mesh");
}

#[test]
#[ignore = "process fleet: run explicitly via `--ignored --test-threads=1` (see CI)"]
fn hierarchical_fleet_shares_in_process_and_steals_across() {
    // 2 processes × 2 workers: each process is one GLB node whose
    // representative owns the sockets; the second worker of each node is
    // fed through the shared-memory NodeBag, never the wire.
    let hp = params().with_n(32).with_workers_per_node(2);
    if let Some(role) = fleet::child_role() {
        run_child(role, hp, 4);
        return;
    }
    let port = fleet::free_port();
    let logs = fleet::run(
        "hierarchical_fleet_shares_in_process_and_steals_across",
        2,
        port,
        FLEET_DEADLINE,
    );
    assert_eq!(logs.len(), 2);
    for l in &logs {
        assert_eq!(l.u64("places"), 2, "each process hosts a 2-worker node");
        // Node-bag shards never cross a process, so each rank's
        // donate/take books balance on their own.
        assert_eq!(l.u64("node_donations"), l.u64("node_takes"), "rank {}", l.rank);
        assert_eq!(l.u64("relayed"), 0, "rank {}: no relay frames", l.rank);
    }
    let fleet_total: u64 = logs.iter().map(|l| l.u64("result")).sum();
    let cfg = GlbConfig::new(4, hp);
    let reference = run_threads(&cfg, |_, _| UtsQueue::new(up()), |q| q.init_root(), &SumReducer);
    assert_eq!(fleet_total, reference.result);
    assert_eq!(fleet_total, sequential_count(&up()));
}
