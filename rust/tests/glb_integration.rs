//! Cross-substrate GLB integration: the thread runtime and the simulator
//! must compute identical results for identical workloads, and the
//! protocol accounting must balance.

use glb::apps::fib::{fib, FibQueue};
use glb::apps::uts::{sequential_count, UtsParams, UtsQueue};
use glb::glb::params::StealPolicy;
use glb::glb::task_queue::SumReducer;
use glb::glb::{GlbConfig, GlbParams};
use glb::place::run_threads;
use glb::sim::{run_sim, CostModel, BGQ, IDEAL, K, POWER775};

fn uts_cost() -> CostModel {
    CostModel::new(150.0, 60, 32)
}

#[test]
fn threads_and_sim_agree_on_uts() {
    let up = UtsParams { b0: 4.0, seed: 19, max_depth: 7 };
    let expect = sequential_count(&up);
    for &p in &[1usize, 3, 8] {
        let cfg = GlbConfig::new(p, GlbParams::default().with_n(64).with_l(2));
        let t = run_threads(&cfg, |_, _| UtsQueue::new(up), |q| q.init_root(), &SumReducer);
        let (s, _) =
            run_sim(&cfg, &BGQ, uts_cost(), |_, _| UtsQueue::new(up), |q| q.init_root(), &SumReducer);
        assert_eq!(t.result, expect, "threads p={p}");
        assert_eq!(s.result, expect, "sim p={p}");
    }
}

#[test]
fn accounting_balances_loot_and_steals() {
    let up = UtsParams { b0: 4.0, seed: 19, max_depth: 8 };
    let cfg = GlbConfig::new(16, GlbParams::default().with_n(32).with_l(2));
    let (out, rep) =
        run_sim(&cfg, &K, uts_cost(), |_, _| UtsQueue::new(up), |q| q.init_root(), &SumReducer);
    let t = out.log.total();
    assert_eq!(t.loot_bags_sent, t.loot_bags_received, "no loot lost");
    assert_eq!(t.loot_items_sent, t.loot_items_received, "no items lost");
    assert_eq!(
        t.random_steals_sent + t.lifeline_steals_sent,
        t.random_steals_received + t.lifeline_steals_received,
        "every steal request is received"
    );
    assert!(
        t.random_steals_perpetrated + t.lifeline_steals_perpetrated <= t.loot_bags_received,
        "successful steals are loot receipts"
    );
    assert!(rep.messages > 0);
}

#[test]
fn every_tuning_knob_preserves_the_result() {
    let up = UtsParams { b0: 4.0, seed: 19, max_depth: 6 };
    let expect = sequential_count(&up);
    for n in [1usize, 17, 511] {
        for w in [0usize, 1, 3] {
            for l in [2usize, 4] {
                let params = GlbParams::default().with_n(n).with_w(w).with_l(l);
                let cfg = GlbConfig::new(6, params);
                let (out, _) = run_sim(
                    &cfg,
                    &POWER775,
                    uts_cost(),
                    |_, _| UtsQueue::new(up),
                    |q| q.init_root(),
                    &SumReducer,
                );
                assert_eq!(out.result, expect, "n={n} w={w} l={l}");
            }
        }
    }
}

#[test]
fn explicit_z_overrides_derived() {
    let up = UtsParams { b0: 4.0, seed: 19, max_depth: 6 };
    let expect = sequential_count(&up);
    for z in [1usize, 2, 4] {
        let cfg = GlbConfig::new(9, GlbParams::default().with_n(64).with_l(2).with_z(z));
        let (out, _) = run_sim(
            &cfg,
            &BGQ,
            uts_cost(),
            |_, _| UtsQueue::new(up),
            |q| q.init_root(),
            &SumReducer,
        );
        assert_eq!(out.result, expect, "z={z}");
    }
}

#[test]
fn random_only_policy_terminates_and_counts() {
    let up = UtsParams { b0: 4.0, seed: 19, max_depth: 7 };
    let expect = sequential_count(&up);
    let params =
        GlbParams::default().with_n(64).with_policy(StealPolicy::RandomOnly { rounds: 3 });
    let cfg = GlbConfig::new(8, params);
    let t = run_threads(&cfg, |_, _| UtsQueue::new(up), |q| q.init_root(), &SumReducer);
    assert_eq!(t.result, expect);
    assert_eq!(t.log.total().lifeline_steals_sent, 0);
}

#[test]
fn hierarchical_threads_and_sim_agree_on_uts() {
    let up = UtsParams { b0: 4.0, seed: 19, max_depth: 7 };
    let expect = sequential_count(&up);
    for &(p, wpn) in &[(8usize, 2usize), (8, 4), (6, 3), (9, 4)] {
        let params = GlbParams::default().with_n(64).with_l(2).with_workers_per_node(wpn);
        let cfg = GlbConfig::new(p, params);
        let t = run_threads(&cfg, |_, _| UtsQueue::new(up), |q| q.init_root(), &SumReducer);
        let (s, _) =
            run_sim(&cfg, &BGQ, uts_cost(), |_, _| UtsQueue::new(up), |q| q.init_root(), &SumReducer);
        assert_eq!(t.result, expect, "threads p={p} wpn={wpn}");
        assert_eq!(s.result, expect, "sim p={p} wpn={wpn}");
    }
}

#[test]
fn hierarchy_moves_work_through_the_node_layer() {
    // With every worker on one of two nodes, intra-node sharing (takes +
    // direct pushes) must carry real traffic, and only the two
    // representatives may ever exchange cross-node messages.
    let up = UtsParams { b0: 4.0, seed: 19, max_depth: 8 };
    let params = GlbParams::default().with_n(32).with_workers_per_node(4);
    let cfg = GlbConfig::new(8, params);
    let (out, _) =
        run_sim(&cfg, &BGQ, uts_cost(), |_, _| UtsQueue::new(up), |q| q.init_root(), &SumReducer);
    assert_eq!(out.result, sequential_count(&up));
    let t = out.log.total();
    assert!(t.node_loot_sent + t.node_takes > 0, "the node layer must move work");
    for (i, s) in out.log.per_place.iter().enumerate() {
        if i % 4 != 0 {
            assert_eq!(
                s.random_steals_sent + s.lifeline_steals_sent,
                0,
                "worker {i} is no representative and must not steal across nodes"
            );
        }
    }
    assert_eq!(out.log.per_node().len(), 2);
}

#[test]
fn hierarchy_reduces_cross_node_traffic_at_equal_worker_count() {
    // The acceptance criterion for the topology layer: at the same total
    // worker count and identical results, building the lifeline graph
    // over nodes (16 workers each, matching BGQ's 16 places/node) must
    // produce fewer cross-node messages per unit of work than the flat
    // protocol, whose random victims and lifelines mostly cross nodes.
    let up = UtsParams { b0: 4.0, seed: 19, max_depth: 9 };
    let expect = sequential_count(&up);
    let run = |wpn: usize| {
        let params = GlbParams::default().with_n(64).with_workers_per_node(wpn);
        let cfg = GlbConfig::new(64, params);
        run_sim(&cfg, &BGQ, uts_cost(), |_, _| UtsQueue::new(up), |q| q.init_root(), &SumReducer)
    };
    let (flat, flat_rep) = run(1);
    let (hier, hier_rep) = run(16);
    assert_eq!(flat.result, expect);
    assert_eq!(hier.result, expect, "hierarchy never changes the reduction");
    // Equal work performed, so comparing totals compares per-unit rates.
    assert!(
        hier_rep.cross_messages < flat_rep.cross_messages,
        "two-level balancing must cut cross-node traffic: hier {} vs flat {}",
        hier_rep.cross_messages,
        flat_rep.cross_messages
    );
}

#[test]
fn fib_stress_repeated_runs() {
    // Thread interleavings differ run to run; the result must not.
    for round in 0..8 {
        let cfg =
            GlbConfig::new(5, GlbParams::default().with_n(8).with_l(2).with_seed(round as u64));
        let out = run_threads(&cfg, |_, _| FibQueue::new(), |q| q.init(18), &SumReducer);
        assert_eq!(out.result, fib(18), "round {round}");
    }
}

#[test]
fn seed_changes_steal_pattern_not_result() {
    let up = UtsParams { b0: 4.0, seed: 19, max_depth: 7 };
    let expect = sequential_count(&up);
    let mut patterns = std::collections::HashSet::new();
    for seed in 0..4u64 {
        let cfg = GlbConfig::new(8, GlbParams::default().with_n(32).with_l(2).with_seed(seed));
        let (out, rep) = run_sim(
            &cfg,
            &BGQ,
            uts_cost(),
            |_, _| UtsQueue::new(up),
            |q| q.init_root(),
            &SumReducer,
        );
        assert_eq!(out.result, expect, "seed {seed}");
        patterns.insert(rep.messages);
    }
    assert!(patterns.len() > 1, "different seeds should explore different schedules");
}

#[test]
fn ideal_arch_zero_latency_runs() {
    let up = UtsParams { b0: 4.0, seed: 19, max_depth: 7 };
    let cfg = GlbConfig::new(64, GlbParams::default().with_n(64).with_l(2));
    let (out, _) = run_sim(
        &cfg,
        &IDEAL,
        uts_cost(),
        |_, _| UtsQueue::new(up),
        |q| q.init_root(),
        &SumReducer,
    );
    assert_eq!(out.result, sequential_count(&up));
}

#[test]
fn large_simulated_place_count() {
    // 2048 places on the BGQ profile — the protocol must stay correct
    // well past the thread runtime's practical range. Granularity 64 on
    // a ~1.4M-node tree gives >20K chunks so work can reach most places.
    let up = UtsParams { b0: 4.0, seed: 19, max_depth: 10 };
    let cfg = GlbConfig::new(2048, GlbParams::default().with_n(64));
    let (out, rep) = run_sim(
        &cfg,
        &BGQ,
        uts_cost(),
        |_, _| UtsQueue::new(up),
        |q| q.init_root(),
        &SumReducer,
    );
    assert_eq!(out.result, sequential_count(&up));
    // A ~1.4M-node tree drains before the ramp saturates all 2048
    // places; several hundred active places already exercises the
    // protocol at this scale (full utilization is a workload-size
    // question, demonstrated by the figure benches).
    let active = out.log.per_place.iter().filter(|s| s.units > 0).count();
    assert!(active > 400, "work should reach hundreds of places, got {active}");
    assert!(rep.events > 10_000);
}

#[test]
fn latency_injection_preserves_correctness() {
    // Every inter-place message delayed 2ms through the router thread —
    // widens race windows on real threads and exercises the delayed
    // Terminate broadcast path.
    use glb::place::{run_threads_opts, ThreadRunOpts};
    let up = UtsParams { b0: 4.0, seed: 19, max_depth: 6 };
    let expect = sequential_count(&up);
    let opts = ThreadRunOpts {
        latency: Some(std::time::Duration::from_millis(2)),
        ..Default::default()
    };
    let cfg = GlbConfig::new(4, GlbParams::default().with_n(32).with_l(2));
    let out = run_threads_opts(&cfg, |_, _| UtsQueue::new(up), |q| q.init_root(), &SumReducer, opts);
    assert_eq!(out.result, expect);
    // With 2ms hops, some waiting must have been recorded.
    let waited: u64 = out.log.per_place.iter().map(|s| s.wait_ns).sum();
    assert!(waited > 1_000_000, "2ms hops should show up in wait time: {waited}ns");
}
