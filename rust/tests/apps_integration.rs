//! Application-level integration: UTS / BC / Fib / N-Queens end-to-end
//! under GLB, against sequential oracles and each other.

use std::sync::Arc;

use glb::apps::bc::{sequential_bc, BcQueue, Graph, InterruptibleBcQueue, RmatParams};
use glb::apps::nqueens::{NQueensQueue, KNOWN};
use glb::apps::uts::{sequential_count, UtsParams, UtsQueue};
use glb::baselines::legacy_bc::{run_legacy_bc_sim, run_legacy_bc_threads};
use glb::glb::task_queue::{SumReducer, VecSumReducer};
use glb::glb::{GlbConfig, GlbParams};
use glb::place::run_threads;
use glb::sim::{run_sim, CostModel, BGQ};
use glb::util::stats::{mean, stddev};

fn close(a: &[f64], b: &[f64], tol: f64) {
    assert_eq!(a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!((x - y).abs() <= tol * (1.0 + y.abs()), "idx {i}: {x} vs {y}");
    }
}

#[test]
fn uts_paper_parameters_small_depths() {
    // b0=4, r=19 (the paper's constants) at several depths, across both
    // substrates and several place counts.
    for d in [4u32, 6, 8] {
        let up = UtsParams { b0: 4.0, seed: 19, max_depth: d };
        let expect = sequential_count(&up);
        let cfg = GlbConfig::new(4, GlbParams::default().with_n(64).with_l(2));
        let t = run_threads(&cfg, |_, _| UtsQueue::new(up), |q| q.init_root(), &SumReducer);
        assert_eq!(t.result, expect, "d={d}");
    }
}

#[test]
fn uts_other_branching_factors() {
    for b0 in [1.5f64, 2.0, 8.0] {
        let up = UtsParams { b0, seed: 19, max_depth: 6 };
        let expect = sequential_count(&up);
        let cfg = GlbConfig::new(3, GlbParams::default().with_n(32).with_l(2));
        let t = run_threads(&cfg, |_, _| UtsQueue::new(up), |q| q.init_root(), &SumReducer);
        assert_eq!(t.result, expect, "b0={b0}");
    }
}

#[test]
fn bc_sparse_and_interruptible_agree() {
    let g = Arc::new(Graph::rmat(RmatParams { scale: 7, ..Default::default() }));
    let (want, _) = sequential_bc(&g);
    let n = g.n() as u32;

    let cfg = GlbConfig::new(4, GlbParams::default().with_n(4).with_l(2));
    let gg = g.clone();
    let sparse =
        run_threads(&cfg, move |_, _| BcQueue::sparse(gg.clone()), |q| q.assign(0, n), &VecSumReducer);
    close(&sparse.result, &want, 1e-9);

    let cfg = GlbConfig::new(4, GlbParams::default().with_n(2000).with_l(2));
    let gg = g.clone();
    let inter = run_threads(
        &cfg,
        move |_, _| InterruptibleBcQueue::new(gg.clone()),
        |q| q.assign(0, n),
        &VecSumReducer,
    );
    close(&inter.result, &want, 1e-9);
}

#[test]
fn bc_on_the_papers_degenerate_graph() {
    // §2.6.1's triangular DAG: GLB must still produce the exact map even
    // though the per-source work is maximally skewed.
    let g = Arc::new(Graph::triangular(96));
    let (want, _) = sequential_bc(&g);
    let n = g.n() as u32;
    let gg = g.clone();
    let cfg = GlbConfig::new(6, GlbParams::default().with_n(1).with_l(2));
    let out =
        run_threads(&cfg, move |_, _| BcQueue::sparse(gg.clone()), |q| q.assign(0, n), &VecSumReducer);
    close(&out.result, &want, 1e-9);
}

#[test]
fn legacy_bc_threads_and_sim_agree_with_glb() {
    let g = Arc::new(Graph::rmat(RmatParams { scale: 7, ..Default::default() }));
    let (want, _) = sequential_bc(&g);
    let legacy_t = run_legacy_bc_threads(&g, 3, 1);
    close(&legacy_t.bc, &want, 1e-9);
    let legacy_s = run_legacy_bc_sim(&g, 5, 2, 3.0, 1.0);
    close(&legacy_s.bc, &want, 1e-9);
}

#[test]
fn glb_flattens_bc_workload_vs_legacy() {
    // The Figs 6/8/10 effect at test scale: σ(busy) under GLB is well
    // below σ under the static randomized legacy layout.
    let g = Arc::new(Graph::rmat(RmatParams { scale: 11, ..Default::default() }));
    let p = 16usize;
    let cost = CostModel::new(4.0, 80, 8);
    let legacy = run_legacy_bc_sim(&g, p, 42, cost.ns_per_unit, BGQ.compute_scale);
    let lb: Vec<f64> = legacy.busy_ns.iter().map(|&x| x as f64).collect();

    let n = g.n() as u32;
    let gg = g.clone();
    let cfg = GlbConfig::new(p, GlbParams::default().with_n(4096).with_w(4).with_l(2));
    let (out, _) = run_sim(
        &cfg,
        &BGQ,
        cost,
        move |i, np| {
            let mut q = InterruptibleBcQueue::new(gg.clone());
            let per = n / np as u32;
            let lo = i as u32 * per;
            let hi = if i == np - 1 { n } else { lo + per };
            q.assign(lo, hi);
            q
        },
        |_| {},
        &VecSumReducer,
    );
    let gb: Vec<f64> = out.log.per_place.iter().map(|s| s.process_ns as f64).collect();
    let (l_rel, g_rel) = (stddev(&lb) / mean(&lb), stddev(&gb) / mean(&gb));
    assert!(
        g_rel < l_rel * 0.6,
        "GLB rel-σ {g_rel:.4} should be well under legacy {l_rel:.4}"
    );
}

#[test]
fn nqueens_scales_with_places() {
    for &p in &[1usize, 2, 6] {
        let cfg = GlbConfig::new(p, GlbParams::default().with_n(64).with_l(2));
        let out =
            run_threads(&cfg, |_, _| NQueensQueue::new(8), |q| q.init_root(), &SumReducer);
        assert_eq!(out.result, KNOWN[8], "p={p}");
    }
}

#[test]
fn nqueens_sim_bigger_board() {
    let cfg = GlbConfig::new(24, GlbParams::default().with_n(256).with_l(2));
    let (out, _) = run_sim(
        &cfg,
        &BGQ,
        CostModel::new(20.0, 40, 16),
        |_, _| NQueensQueue::new(10),
        |q| q.init_root(),
        &SumReducer,
    );
    assert_eq!(out.result, KNOWN[10]);
}

#[test]
fn bc_star_and_cycle_analytic_under_glb() {
    for (g, check) in [
        (Graph::star(6), {
            let mut v = vec![0.0; 7];
            v[0] = 30.0; // k(k-1) = 6*5
            v
        }),
        (Graph::path(4), vec![0.0, 4.0, 4.0, 0.0]),
    ] {
        let g = Arc::new(g);
        let n = g.n() as u32;
        let gg = g.clone();
        let cfg = GlbConfig::new(2, GlbParams::default().with_n(1).with_l(2));
        let out = run_threads(
            &cfg,
            move |_, _| BcQueue::sparse(gg.clone()),
            |q| q.assign(0, n),
            &VecSumReducer,
        );
        close(&out.result, &check, 1e-12);
    }
}
