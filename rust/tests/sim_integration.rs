//! Simulator-substrate integration: determinism, calibration honesty,
//! and the architecture-profile shapes the figures rely on.

use glb::apps::uts::{sequential_count, UtsParams, UtsQueue};
use glb::glb::task_queue::SumReducer;
use glb::glb::{GlbConfig, GlbParams};
use glb::harness::calibrate_uts_cost;
use glb::sim::{run_sim, ArchProfile, CostModel, BGQ, K, POWER775};

fn run_uts(
    p: usize,
    d: u32,
    arch: &ArchProfile,
    params: GlbParams,
    cost: CostModel,
) -> (glb::glb::RunOutput<u64>, glb::sim::SimReport) {
    let up = UtsParams { b0: 4.0, seed: 19, max_depth: d };
    let cfg = GlbConfig::new(p, params);
    run_sim(&cfg, arch, cost, |_, _| UtsQueue::new(up), |q| q.init_root(), &SumReducer)
}

#[test]
fn bitwise_deterministic_replay() {
    let cost = CostModel::new(150.0, 60, 32);
    for arch in [&POWER775, &BGQ, &K] {
        let (a, ra) = run_uts(48, 8, arch, GlbParams::default().with_n(64), cost);
        let (b, rb) = run_uts(48, 8, arch, GlbParams::default().with_n(64), cost);
        assert_eq!(a.elapsed_ns, b.elapsed_ns, "{}", arch.name);
        assert_eq!(ra.events, rb.events);
        assert_eq!(ra.messages, rb.messages);
        assert_eq!(a.result, b.result);
        // Per-place stats replay too.
        for (x, y) in a.log.per_place.iter().zip(&b.log.per_place) {
            assert_eq!(x.units, y.units);
            assert_eq!(x.random_steals_sent, y.random_steals_sent);
        }
    }
}

#[test]
fn calibrated_single_place_rate_matches_reality() {
    // The simulator's P=1 virtual throughput must track a real
    // single-threaded run within 2x (the cost model is best-of-k, real
    // runs carry noise — this guards against order-of-magnitude drift).
    let cost = calibrate_uts_cost();
    let up = UtsParams { b0: 4.0, seed: 19, max_depth: 9 };

    let t0 = std::time::Instant::now();
    let nodes = sequential_count(&up);
    let real_rate = nodes as f64 * 1e9 / t0.elapsed().as_nanos() as f64;

    let (out, _) = run_uts(1, 9, &POWER775, GlbParams::default(), cost);
    let sim_rate = out.units_per_sec();
    let ratio = sim_rate / real_rate;
    assert!(
        (0.5..2.0).contains(&ratio),
        "sim P=1 rate {sim_rate:.3e} vs real {real_rate:.3e} (ratio {ratio:.2})"
    );
}

#[test]
fn speedup_is_near_linear_in_the_paper_regime() {
    // Per-place work held constant (depth grows with p): efficiency at
    // 64 places must stay high — the Figs 2/3 plateau.
    let cost = CostModel::new(150.0, 60, 32);
    let (one, _) = run_uts(1, 8, &BGQ, GlbParams::default(), cost);
    let (sixty_four, _) = run_uts(64, 11, &BGQ, GlbParams::default(), cost);
    let eff = sixty_four.units_per_sec() / 64.0 / one.units_per_sec();
    assert!(eff > 0.55, "64-place efficiency too low: {eff:.3}");
}

#[test]
fn k_interconnect_is_slower_than_power_hub() {
    // Fig 4 vs Fig 2: K's per-hop latency + NIC occupancy make large
    // sweeps less efficient than Power 775's all-to-all hub. Slower
    // cores *amortize* coordination (K's real profile partially hides
    // its interconnect), so isolate the interconnect by pinning both
    // profiles to the same core speed.
    // In a compute-bound regime schedule chaos (ms-scale tail luck)
    // dwarfs the µs-scale interconnect difference, so measure in a
    // *latency-bound* regime (2 ns/node) and average over seeds.
    let cost = CostModel::new(2.0, 20, 32);
    let mut pw = POWER775;
    let mut kk = K;
    pw.compute_scale = 1.0;
    kk.compute_scale = 1.0;
    let mean = |arch: &ArchProfile| -> f64 {
        (0..5u64)
            .map(|s| {
                let (out, _) =
                    run_uts(256, 10, arch, GlbParams::default().with_n(64).with_seed(s), cost);
                out.elapsed_ns as f64
            })
            .sum::<f64>()
            / 5.0
    };
    let (pw_ns, kk_ns) = (mean(&pw), mean(&kk));
    assert!(
        kk_ns > pw_ns * 1.1,
        "latency-bound: K interconnect ({kk_ns:.0} ns) should clearly trail the P775 hub ({pw_ns:.0} ns)"
    );
}

#[test]
fn nic_contention_model_kicks_in() {
    // Zeroing the NIC occupancy should help on average — sanity for the
    // queueing model behind the Fig 4 droop. A single schedule can go
    // either way (faster messages perturb the chaotic steal pattern), so
    // compare means over several victim-selection seeds.
    let cost = CostModel::new(150.0, 60, 32);
    let mut free_nic = K;
    free_nic.nic_msg_overhead_ns = 0;
    free_nic.nic_bytes_per_ns = f64::INFINITY;
    let mean_elapsed = |arch: &ArchProfile| -> f64 {
        (0..5u64)
            .map(|s| {
                let (out, _) = run_uts(128, 11, arch, GlbParams::default().with_seed(s), cost);
                out.elapsed_ns as f64
            })
            .sum::<f64>()
            / 5.0
    };
    let with = mean_elapsed(&K);
    let without = mean_elapsed(&free_nic);
    assert!(
        without <= with * 1.02,
        "free NIC mean {without:.0} should not exceed contended mean {with:.0} by >2%"
    );
}

#[test]
fn compute_scale_shifts_absolute_rates() {
    let cost = CostModel::new(150.0, 60, 32);
    let (bgq, _) = run_uts(16, 9, &BGQ, GlbParams::default(), cost);
    let (p7, _) = run_uts(16, 9, &POWER775, GlbParams::default(), cost);
    assert!(
        p7.units_per_sec() > 1.5 * bgq.units_per_sec(),
        "P7 cores are modelled ~2.6x faster: {} vs {}",
        p7.units_per_sec(),
        bgq.units_per_sec()
    );
}

#[test]
fn virtual_time_is_invariant_to_host_load() {
    // Two runs interleaved with host jitter must produce identical
    // virtual timings (virtual time never reads the wall clock).
    let cost = CostModel::new(150.0, 60, 32);
    let (a, _) = run_uts(32, 8, &BGQ, GlbParams::default(), cost);
    std::thread::sleep(std::time::Duration::from_millis(20));
    let (b, _) = run_uts(32, 8, &BGQ, GlbParams::default(), cost);
    assert_eq!(a.elapsed_ns, b.elapsed_ns);
}
