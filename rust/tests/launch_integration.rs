//! Launcher integration tests: the `glb launch` CLI end-to-end over a
//! localhost fleet, and the engine's failure paths through the
//! `testkit::fleet` harness (which PR 5 refactored onto the launcher —
//! these tests pin the fail-fast semantics that refactor bought).
//!
//! Process-spawning tests are `#[ignore]`d like the socket fleet tests;
//! CI runs them explicitly with `--ignored --test-threads=1`.

use std::panic::AssertUnwindSafe;
use std::time::{Duration, Instant};

use glb::apps::uts::{sequential_count, UtsParams, UtsQueue};
use glb::glb::task_queue::SumReducer;
use glb::glb::{GlbConfig, GlbParams};
use glb::launch::report::load_fleet_report;
use glb::place::run_threads;
use glb::testkit::fleet;
use glb::util::json::Value;

/// A rank that dies mid-run must fail the fleet immediately: the engine
/// kills the survivors instead of letting them burn the whole deadline
/// (the pre-PR-5 harness waited out `deadline` before reporting).
#[test]
#[ignore = "process fleet: run explicitly via `--ignored --test-threads=1` (see CI)"]
fn fleet_failure_propagates_without_waiting_for_the_deadline() {
    if let Some(role) = fleet::child_role() {
        if role.rank == 1 {
            eprintln!("rank 1 failing on purpose");
            std::process::exit(3);
        }
        // Survivors would sit far past the point where rank 1 died; only
        // a fail-fast kill gets the orchestrator its answer quickly.
        std::thread::sleep(Duration::from_secs(60));
        fleet::emit(role.rank, &[("result", "0".into())]);
        return;
    }
    let t0 = Instant::now();
    let result = std::panic::catch_unwind(AssertUnwindSafe(|| {
        fleet::run(
            "fleet_failure_propagates_without_waiting_for_the_deadline",
            2,
            fleet::free_port(),
            Duration::from_secs(60),
        )
    }));
    let elapsed = t0.elapsed();
    let err = result.expect_err("a failing rank must fail the fleet");
    let msg = err
        .downcast_ref::<String>()
        .cloned()
        .unwrap_or_else(|| "<non-string panic>".into());
    assert!(msg.contains("rank 1"), "failure must name the dead rank: {msg}");
    assert!(msg.contains("failing on purpose"), "failure must carry the rank's stderr: {msg}");
    assert!(
        elapsed < Duration::from_secs(30),
        "failure took {elapsed:?} — the harness waited for the survivors/deadline"
    );
}

/// The acceptance path: `glb launch --np 4 uts ... --report fleet.json`
/// writes one aggregated report whose UTS node count is bit-identical to
/// the thread runtime at equal worker count.
#[test]
#[ignore = "process fleet: run explicitly via `--ignored --test-threads=1` (see CI)"]
fn glb_launch_writes_an_aggregated_fleet_report() {
    const DEPTH: u32 = 6;
    let bin = env!("CARGO_BIN_EXE_glb");
    let report = std::env::temp_dir()
        .join(format!("glb-launch-itest-{}-fleet.json", std::process::id()));
    let output = std::process::Command::new(bin)
        .args(["launch", "--np", "4", "uts", "--depth", "6", "--transport", "tcp", "--report"])
        .arg(&report)
        .output()
        .expect("run glb launch");
    assert!(
        output.status.success(),
        "glb launch failed ({}):\n--- stdout\n{}\n--- stderr\n{}",
        output.status,
        String::from_utf8_lossy(&output.stdout),
        String::from_utf8_lossy(&output.stderr),
    );

    let fleet_report = load_fleet_report(&report).expect("fleet report parses");
    assert_eq!(fleet_report.get("app").and_then(Value::as_str), Some("uts"));
    assert_eq!(fleet_report.get("ranks").and_then(Value::as_u64), Some(4));
    assert_eq!(fleet_report.get("places").and_then(Value::as_u64), Some(4));
    let per_rank = fleet_report.get("per_rank").and_then(Value::as_arr).expect("per_rank");
    assert_eq!(per_rank.len(), 4);

    // Bit-identical to the thread runtime at equal worker count (and to
    // the sequential tree — any lost/duplicated loot would show here).
    let up = UtsParams { b0: 4.0, seed: 19, max_depth: DEPTH };
    let cfg = GlbConfig::new(4, GlbParams::default());
    let reference = run_threads(&cfg, |_, _| UtsQueue::new(up), |q| q.init_root(), &SumReducer);
    assert_eq!(reference.result, sequential_count(&up));
    assert_eq!(
        fleet_report.get("result").and_then(Value::as_u64),
        Some(reference.result),
        "fleet report result must match the thread runtime bit-for-bit"
    );

    // The fleet actually moved work over TCP, and every byte sent landed.
    let tx = fleet_report.get("wire_tx_bytes").and_then(Value::as_u64).unwrap();
    let rx = fleet_report.get("wire_rx_bytes").and_then(Value::as_u64).unwrap();
    assert!(tx > 0, "a 4-rank UTS fleet must exchange data frames");
    assert_eq!(tx, rx, "wire bytes conserved across the mesh");

    // Totals aggregate the per-rank logs: loot conservation holds on the
    // summed counters, and the fleet did real work.
    let totals = fleet_report.get("totals").expect("aggregated totals");
    assert_eq!(
        totals.get("loot_bags_sent").and_then(Value::as_u64),
        totals.get("loot_bags_received").and_then(Value::as_u64),
        "fleet-wide loot conservation in the aggregated log"
    );
    assert!(totals.get("units").and_then(Value::as_u64).unwrap_or(0) > 0);

    std::fs::remove_file(&report).ok();
}

/// The reactor acceptance fleet: 8 ranks on one host, real mesh fan-out
/// (7 mesh links per rank), one I/O thread per rank, batched frames
/// conserved fleet-wide, and a result bit-identical to the thread
/// runtime. Before the event-loop transport this shape cost each rank
/// ~14 reader threads; the per-rank `io_threads` field pins the
/// O(workers)-not-O(peers) property.
#[test]
#[ignore = "process fleet: run explicitly via `--ignored --test-threads=1` (see CI)"]
fn eight_rank_fleet_runs_one_io_thread_per_rank() {
    const DEPTH: u32 = 7;
    let bin = env!("CARGO_BIN_EXE_glb");
    let report = std::env::temp_dir()
        .join(format!("glb-launch-itest-{}-fleet8.json", std::process::id()));
    let output = std::process::Command::new(bin)
        .args(["launch", "--np", "8", "uts", "--depth", "7", "--transport", "tcp", "--report"])
        .arg(&report)
        .output()
        .expect("run glb launch");
    assert!(
        output.status.success(),
        "glb launch failed ({}):\n--- stdout\n{}\n--- stderr\n{}",
        output.status,
        String::from_utf8_lossy(&output.stdout),
        String::from_utf8_lossy(&output.stderr),
    );

    let fleet_report = load_fleet_report(&report).expect("fleet report parses");
    assert_eq!(fleet_report.get("ranks").and_then(Value::as_u64), Some(8));
    let per_rank = fleet_report.get("per_rank").and_then(Value::as_arr).expect("per_rank");
    assert_eq!(per_rank.len(), 8);
    for r in per_rank {
        assert_eq!(
            r.get("io_threads").and_then(Value::as_u64),
            Some(1),
            "rank {:?}: exactly one reactor thread, regardless of 7 peers",
            r.get("rank")
        );
    }

    // Frame conservation across the mesh: every frame flushed by some
    // rank's reactor was decoded by another's.
    let sent = fleet_report.get("frames_sent").and_then(Value::as_u64).unwrap();
    let recv = fleet_report.get("frames_recv").and_then(Value::as_u64).unwrap();
    assert!(sent > 0, "an 8-rank fleet must exchange frames");
    assert_eq!(sent, recv, "frames conserved across the mesh");
    let batches = fleet_report.get("batches").and_then(Value::as_u64).unwrap();
    assert!(batches > 0);
    assert!(batches <= sent, "a batch carries at least one frame");

    let up = UtsParams { b0: 4.0, seed: 19, max_depth: DEPTH };
    let cfg = GlbConfig::new(8, GlbParams::default());
    let reference = run_threads(&cfg, |_, _| UtsQueue::new(up), |q| q.init_root(), &SumReducer);
    assert_eq!(reference.result, sequential_count(&up));
    assert_eq!(
        fleet_report.get("result").and_then(Value::as_u64),
        Some(reference.result),
        "8-rank fleet result must match the thread runtime bit-for-bit"
    );

    std::fs::remove_file(&report).ok();
}

/// The live-telemetry acceptance path: a `--stats` fleet's report grows
/// a `"live_stats"` time series, and the series' final cumulative gauges
/// equal the post-mortem `RunLog` totals *exactly* — workers publish
/// their gauges through the final `Done` iteration, so the last
/// telemetry sample and the teardown accounting are the same numbers,
/// not two clocks that roughly agree.
#[test]
#[ignore = "process fleet: run explicitly via `--ignored --test-threads=1` (see CI)"]
fn live_stats_series_matches_the_post_mortem_run_log() {
    const DEPTH: u32 = 7;
    let bin = env!("CARGO_BIN_EXE_glb");
    let report = std::env::temp_dir()
        .join(format!("glb-launch-itest-{}-stats.json", std::process::id()));
    let output = std::process::Command::new(bin)
        .args(["launch", "--np", "4", "--stats=100", "uts", "--depth", "7", "--report"])
        .arg(&report)
        .output()
        .expect("run glb launch --stats");
    assert!(
        output.status.success(),
        "glb launch --stats failed ({}):\n--- stdout\n{}\n--- stderr\n{}",
        output.status,
        String::from_utf8_lossy(&output.stdout),
        String::from_utf8_lossy(&output.stderr),
    );
    // The human summary lines are echoed; the machine markers are not.
    let stdout = String::from_utf8_lossy(&output.stdout);
    assert!(stdout.contains("glb stats t="), "echoed human stats line:\n{stdout}");
    assert!(!stdout.contains("GLB-LIVE-STATS"), "marker lines must be filtered:\n{stdout}");

    let fleet_report = load_fleet_report(&report).expect("fleet report parses");
    let live = fleet_report.get("live_stats").and_then(Value::as_arr).expect("live_stats series");
    assert!(!live.is_empty(), "a --stats run must record at least the final sample");

    // The series is a time axis of cumulative gauges: both must be
    // monotonic, and the closing sample is the fleet-final one.
    let u = |v: &Value, k: &str| v.get(k).and_then(Value::as_u64).unwrap_or_else(|| panic!("{k}"));
    for w in live.windows(2) {
        assert!(u(&w[1], "t_ms") >= u(&w[0], "t_ms"), "t_ms must not go backwards");
        assert!(u(&w[1], "tasks") >= u(&w[0], "tasks"), "cumulative tasks must not shrink");
    }
    let fin = live.last().unwrap();
    assert_eq!(fin.get("last"), Some(&Value::Bool(true)), "series ends on the final snapshot");
    assert_eq!(u(fin, "ranks"), 4);
    assert_eq!(u(fin, "ranks_heard"), 4, "every rank's stats frames must reach rank 0");
    assert!(u(fin, "wire_tx") > 0, "the fleet moved bytes before the final sample");

    // Exactness: final cumulative telemetry == aggregated RunLog totals.
    let totals = fleet_report.get("totals").expect("aggregated totals");
    let t = |k: &str| totals.get(k).and_then(Value::as_u64).unwrap_or_else(|| panic!("{k}"));
    assert_eq!(u(fin, "tasks"), t("items_processed"), "final tasks == RunLog items");
    assert_eq!(
        u(fin, "steals_out"),
        t("random_steals_sent") + t("lifeline_steals_sent"),
        "final steals_out == RunLog steal attempts"
    );
    assert_eq!(
        u(fin, "steals_in"),
        t("random_steals_perpetrated") + t("lifeline_steals_perpetrated"),
        "final steals_in == RunLog perpetrated steals"
    );
    assert_eq!(u(fin, "loot_sent"), t("loot_bags_sent"));
    assert_eq!(u(fin, "loot_recv"), t("loot_bags_received"));
    assert_eq!(u(fin, "starvations"), t("starvations"));
    assert_eq!(u(fin, "bag_depth"), 0, "every bag is dry at termination");

    // Telemetry must not perturb the computation: still bit-identical to
    // the thread runtime at equal worker count.
    let up = UtsParams { b0: 4.0, seed: 19, max_depth: DEPTH };
    let cfg = GlbConfig::new(4, GlbParams::default());
    let reference = run_threads(&cfg, |_, _| UtsQueue::new(up), |q| q.init_root(), &SumReducer);
    assert_eq!(reference.result, sequential_count(&up));
    assert_eq!(fleet_report.get("result").and_then(Value::as_u64), Some(reference.result));

    std::fs::remove_file(&report).ok();
}

/// A launch spec error must be reported before anything spawns.
#[test]
fn glb_launch_rejects_derived_flags_loudly() {
    let bin = env!("CARGO_BIN_EXE_glb");
    let output = std::process::Command::new(bin)
        .args(["launch", "--np", "2", "uts", "--rank", "1"])
        .output()
        .expect("run glb launch");
    assert!(!output.status.success(), "--rank in passthrough must be rejected");
    let stderr = String::from_utf8_lossy(&output.stderr);
    assert!(stderr.contains("derived"), "{stderr}");
}
