//! Resident-fleet (`glb serve`) integration tests.
//!
//! A fleet boots **once** and then serves a stream of jobs submitted
//! over the client plane ([`SubmitClient`] speaking `Ctrl::Submit` /
//! `Ctrl::JobResult` to rank 0). These tests run every rank as an
//! in-process thread — the ranks still talk real localhost TCP through
//! the same bootstrap, mesh, and control links as a process fleet, but
//! a single process lets the tests observe process-global audit
//! counters ([`cross_epoch_frames`]) and per-rank [`JobReport`]s across
//! the whole fleet.
//!
//! What must hold:
//!
//! - results match one-shot runs (UTS counts the sequential tree
//!   bit-identically, fib computes fib(n) exactly, BC reductions agree
//!   within the repo-wide float tolerance — their f64 summation
//!   grouping follows the steal schedule),
//! - back-to-back jobs never cross-steal or cross-credit: the
//!   cross-epoch audit counter stays zero and loot conservation holds
//!   *per epoch* (fleet-wide bags sent == bags received within every
//!   job),
//! - the fleet survives hundreds of queued jobs without restarting a
//!   rank (the soak test, `--ignored`, exercised by CI).

use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use glb::apps::uts::{sequential_count, UtsParams};
use glb::glb::GlbParams;
use glb::place::{
    cross_epoch_frames, serve_with, JobSpec, ServiceResult, SocketRunOpts, SubmitClient,
};
use glb::testkit::fleet;

const CONNECT_DEADLINE: Duration = Duration::from_secs(30);

fn up() -> UtsParams {
    UtsParams { b0: 4.0, seed: 19, max_depth: 6 }
}

fn params() -> GlbParams {
    GlbParams::default().with_n(64).with_l(2)
}

fn fib(n: u64) -> u64 {
    let (mut a, mut b) = (0u64, 1u64);
    for _ in 0..n {
        (a, b) = (b, a + b);
    }
    a
}

/// One collected per-rank, per-job observation.
struct Obs {
    epoch: u64,
    rank: usize,
    loot_sent: u64,
    loot_recv: u64,
}

/// Boot an in-process fleet of `ranks` serve threads on `port` and
/// return their join handles plus the shared observation log.
fn spawn_fleet(ranks: usize, port: u16) -> (Vec<thread::JoinHandle<()>>, Arc<Mutex<Vec<Obs>>>) {
    let log: Arc<Mutex<Vec<Obs>>> = Arc::new(Mutex::new(Vec::new()));
    let handles = (0..ranks)
        .map(|rank| {
            let log = Arc::clone(&log);
            thread::spawn(move || {
                let opts = SocketRunOpts {
                    rank,
                    ranks,
                    port,
                    host: "127.0.0.1".to_string(),
                    ..Default::default()
                };
                serve_with(&opts, |report| {
                    log.lock().unwrap().push(Obs {
                        epoch: report.epoch,
                        rank: report.rank,
                        loot_sent: report.stats.loot_bags_sent,
                        loot_recv: report.stats.loot_bags_received,
                    });
                })
                .unwrap_or_else(|e| panic!("serve rank {rank} failed: {e}"));
            })
        })
        .collect();
    (handles, log)
}

/// Dial rank 0's client plane, retrying while the fleet bootstraps.
fn connect(port: u16) -> SubmitClient {
    let deadline = Instant::now() + CONNECT_DEADLINE;
    loop {
        match SubmitClient::connect("127.0.0.1", port, CONNECT_DEADLINE) {
            Ok(c) => return c,
            Err(_) if Instant::now() < deadline => thread::sleep(Duration::from_millis(20)),
            Err(e) => panic!("submit client could not reach the fleet: {e}"),
        }
    }
}

/// Fleet-wide loot conservation *within* every epoch, and full rank
/// participation in every job. A frame leaking across jobs would break
/// the per-epoch balance (its bag is sent in one epoch, merged — or
/// dropped — in another).
fn assert_epoch_isolation(log: &[Obs], ranks: usize, jobs: u64) {
    for epoch in 1..=jobs {
        let in_epoch: Vec<&Obs> = log.iter().filter(|o| o.epoch == epoch).collect();
        assert_eq!(in_epoch.len(), ranks, "every rank reports exactly once for job {epoch}");
        let mut seen: Vec<usize> = in_epoch.iter().map(|o| o.rank).collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..ranks).collect::<Vec<_>>());
        let sent: u64 = in_epoch.iter().map(|o| o.loot_sent).sum();
        let recv: u64 = in_epoch.iter().map(|o| o.loot_recv).sum();
        assert_eq!(sent, recv, "loot conservation within job {epoch}");
    }
    assert_eq!(log.len() as u64, jobs * ranks as u64, "no reports outside the submitted epochs");
}

#[test]
fn back_to_back_jobs_are_epoch_isolated_and_bit_identical() {
    let port = fleet::free_port();
    let (handles, log) = spawn_fleet(2, port);
    let mut client = connect(port);

    // Two identical UTS jobs back to back, then a fib chaser: the
    // counts must repeat bit-for-bit and match the sequential tree.
    let spec = JobSpec::uts(up(), params());
    let expect = sequential_count(&up());
    for job in 1..=2u64 {
        match client.submit(&spec).expect("submit uts") {
            ServiceResult::U64(v) => {
                assert_eq!(v, expect, "job {job} must count the sequential tree")
            }
            other => panic!("uts returned {other:?}"),
        }
    }
    match client.submit(&JobSpec::fib(20, params())).expect("submit fib") {
        ServiceResult::U64(v) => assert_eq!(v, fib(20)),
        other => panic!("fib returned {other:?}"),
    }

    client.shutdown().expect("shutdown fleet");
    for h in handles {
        h.join().expect("serve thread panicked");
    }

    let log = log.lock().unwrap();
    assert_epoch_isolation(&log, 2, 3);
    assert_eq!(cross_epoch_frames(), 0, "no frame may land outside its own job epoch");
}

#[test]
#[ignore = "many-jobs soak: run explicitly via `--ignored` (see CI serve-smoke)"]
fn resident_fleet_soaks_hundreds_of_mixed_jobs() {
    let port = fleet::free_port();
    let ranks = 4;
    let (handles, log) = spawn_fleet(ranks, port);
    let mut client = connect(port);

    let uts = JobSpec::uts(up(), params());
    let fib_spec = JobSpec::fib(20, params());
    let bc = JobSpec::bc(7, params());
    let uts_expect = sequential_count(&up());
    let fib_expect = fib(20);

    // Round-robin through the three apps for 120 jobs on one warm
    // fleet. Every UTS/fib answer has a closed-form reference; BC's
    // f64 summation grouping follows the steal schedule, so its
    // reductions agree within the repo-wide relative tolerance rather
    // than bit-for-bit — a cross-job leak would still show up as a
    // wildly drifting vector (a bag merged into the wrong job's run).
    let mut bc_reference: Option<Vec<f64>> = None;
    let jobs = 120u64;
    for job in 1..=jobs {
        match job % 3 {
            0 => match client.submit(&bc).expect("submit bc") {
                ServiceResult::VecF64(v) => {
                    assert!(!v.is_empty(), "job {job}: empty BC reduction");
                    match &bc_reference {
                        None => bc_reference = Some(v),
                        Some(first) => {
                            assert_eq!(v.len(), first.len(), "job {job}");
                            for (i, (a, b)) in v.iter().zip(first).enumerate() {
                                let scale = b.abs().max(1e-12);
                                assert!(
                                    ((a - b) / scale).abs() < 1e-3,
                                    "job {job}: BC[{i}] drifted: {a} vs {b}"
                                );
                            }
                        }
                    }
                }
                other => panic!("job {job}: bc returned {other:?}"),
            },
            1 => match client.submit(&uts).expect("submit uts") {
                ServiceResult::U64(v) => assert_eq!(v, uts_expect, "job {job}"),
                other => panic!("job {job}: uts returned {other:?}"),
            },
            _ => match client.submit(&fib_spec).expect("submit fib") {
                ServiceResult::U64(v) => assert_eq!(v, fib_expect, "job {job}"),
                other => panic!("job {job}: fib returned {other:?}"),
            },
        }
    }

    client.shutdown().expect("shutdown fleet");
    for h in handles {
        h.join().expect("serve thread panicked");
    }

    let log = log.lock().unwrap();
    assert_epoch_isolation(&log, ranks, jobs);
    assert_eq!(
        cross_epoch_frames(),
        0,
        "no frame may land outside its own job epoch across {jobs} jobs"
    );
}
