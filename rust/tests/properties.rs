//! Property-based tests (seeded random cases via `glb::testkit`) over the
//! protocol invariants DESIGN.md §7 calls out:
//!
//! * conservation — no task is lost or duplicated under any split/merge
//!   or steal schedule;
//! * termination — every configuration quiesces, and the token ledger is
//!   exactly zero afterwards;
//! * determinism — the simulator replays bit-identically;
//! * topology — the lifeline graph stays connected with bounded
//!   out-degree for arbitrary (P, l, z);
//! * wire — the socket codec round-trips every message and bag shape,
//!   and truncated/corrupt frames error instead of panicking.

use std::collections::{HashSet, VecDeque};

use glb::apps::bc::BcBag;
use glb::apps::uts::{sequential_count, UtsBag, UtsNode, UtsParams, UtsQueue};
use glb::glb::lifeline::LifelineGraph;
use glb::glb::message::Msg;
use glb::glb::params::StealPolicy;
use glb::glb::task_bag::{ArrayListTaskBag, TaskBag};
use glb::glb::task_queue::SumReducer;
use glb::glb::wire::{self, WireCodec};
use glb::glb::{GlbConfig, GlbParams};
use glb::sim::{run_sim, ArchProfile, CostModel, BGQ, K, POWER775};
use glb::testkit::{check_cases, Gen};

#[test]
fn prop_bag_split_merge_conserves_items() {
    check_cases("bag-conservation", 200, |g: &mut Gen| {
        let len = g.usize(0..200);
        let mut bag = ArrayListTaskBag::from_vec((0..len as u64).collect::<Vec<_>>());
        let mut shards: Vec<ArrayListTaskBag<u64>> = Vec::new();
        // Random interleaving of splits and merges.
        for _ in 0..g.usize(1..30) {
            if g.bool(0.6) {
                if let Some(loot) = bag.split() {
                    shards.push(loot);
                }
            } else if let Some(s) = shards.pop() {
                bag.merge(s);
            }
        }
        // Gather everything back and verify the multiset.
        for s in shards {
            bag.merge(s);
        }
        let mut items = bag.into_vec();
        items.sort_unstable();
        assert_eq!(items, (0..len as u64).collect::<Vec<_>>());
    });
}

#[test]
fn prop_bc_interval_bag_conserves_vertices() {
    use glb::apps::bc::BcBag;
    check_cases("bc-bag-conservation", 200, |g: &mut Gen| {
        let n = g.usize(1..500) as u32;
        let mut bag = BcBag::interval(0, n);
        let mut shards = Vec::new();
        for _ in 0..g.usize(1..20) {
            if g.bool(0.5) {
                if let Some(loot) = bag.split() {
                    shards.push(loot);
                }
            } else if let Some(s) = shards.pop() {
                bag.merge(s);
            }
            // Occasionally consume some vertices like a worker would.
            if g.bool(0.3) {
                let mut out = Vec::new();
                bag.take(g.usize(0..5), &mut out);
                // consumed vertices are accounted outside the bag
                shards.push(BcBag::new()); // keep shard list non-trivial
                let total: u64 = bag.vertices()
                    + shards.iter().map(|s| s.vertices()).sum::<u64>()
                    + out.len() as u64;
                let _ = total;
            }
        }
        let consumed_free: u64 =
            bag.vertices() + shards.iter().map(|s| s.vertices()).sum::<u64>();
        assert!(consumed_free <= n as u64, "never create vertices");
    });
}

// ---------------------------------------------------------------------
// wire codec (the socket transport's frame format)
// ---------------------------------------------------------------------

fn random_uts_bag(g: &mut Gen) -> UtsBag {
    let entries = g.usize(0..40);
    let nodes = (0..entries)
        .map(|_| {
            let mut desc = [0u8; 20];
            for b in desc.iter_mut() {
                *b = g.u64(0..256) as u8;
            }
            let lo = g.u64(0..100_000) as u32;
            let width = g.u64(1..64) as u32;
            UtsNode { desc, depth: g.u64(0..64) as u32, lo, hi: lo + width }
        })
        .collect();
    UtsBag::from_nodes(nodes)
}

fn random_bc_bag(g: &mut Gen) -> BcBag {
    let entries = g.usize(0..40);
    let intervals = (0..entries)
        .map(|_| {
            let lo = g.u64(0..1_000_000) as u32;
            let width = g.u64(1..5_000) as u32;
            (lo, lo + width)
        })
        .collect();
    BcBag::from_intervals(intervals)
}

/// A random message over `bag` covering every variant / flag combination
/// (loot with a bag may carry termination credit; refusals never do).
fn random_msg<B>(g: &mut Gen, bag: B) -> Msg<B> {
    match g.usize(0..5) {
        0 => Msg::Steal {
            thief: g.usize(0..1 << 20),
            lifeline: g.bool(0.5),
            nonce: g.u64(0..u64::MAX),
        },
        1 => Msg::Loot {
            victim: g.usize(0..1 << 20),
            bag: None,
            lifeline: g.bool(0.5),
            nonce: Some(g.u64(0..u64::MAX)),
            credit: 0,
        },
        2 => Msg::Loot {
            victim: g.usize(0..1 << 20),
            bag: Some(bag),
            lifeline: true,
            nonce: None,
            credit: g.u64(0..1 << 44),
        },
        3 => Msg::Loot {
            victim: g.usize(0..1 << 20),
            bag: Some(bag),
            lifeline: g.bool(0.5),
            nonce: Some(g.u64(0..u64::MAX)),
            credit: g.u64(0..u64::MAX),
        },
        _ => Msg::Terminate,
    }
}

fn assert_roundtrip<B: WireCodec + PartialEq + std::fmt::Debug>(msg: &Msg<B>) {
    let frame = wire::encode_frame(msg);
    let back: Msg<B> = wire::decode_frame(&frame).expect("decode own encoding");
    assert_eq!(&back, msg);
}

#[test]
fn prop_wire_roundtrip_every_msg_variant_uts() {
    check_cases("wire-roundtrip-uts", 300, |g: &mut Gen| {
        let bag = random_uts_bag(g);
        let msg = random_msg(g, bag);
        assert_roundtrip(&msg);
    });
}

#[test]
fn prop_wire_roundtrip_every_msg_variant_bc() {
    check_cases("wire-roundtrip-bc", 300, |g: &mut Gen| {
        let bag = random_bc_bag(g);
        let msg = random_msg(g, bag);
        assert_roundtrip(&msg);
    });
}

#[test]
fn prop_wire_roundtrip_arraylist_bags() {
    check_cases("wire-roundtrip-arraylist", 200, |g: &mut Gen| {
        let len = g.usize(0..100);
        let items = g.vec(len, |g| g.u64(0..u64::MAX));
        let msg = random_msg(g, ArrayListTaskBag::from_vec(items));
        assert_roundtrip(&msg);
    });
}

#[test]
fn prop_wire_truncated_frames_error_not_panic() {
    check_cases("wire-truncation", 120, |g: &mut Gen| {
        let bag = random_uts_bag(g);
        let msg = random_msg(g, bag);
        let frame = wire::encode_frame(&msg);
        // Every strict prefix must decode to an error (never a panic,
        // never a silently-short message).
        for cut in 0..frame.len() {
            assert!(wire::decode_frame::<UtsBag>(&frame[..cut]).is_err(), "cut={cut}");
        }
        // A single flipped byte may decode (e.g. inside a descriptor) or
        // error — but must never panic. The length prefix is exempt: a
        // larger claimed length is just Truncated, checked above.
        let mut corrupt = frame.clone();
        let at = g.usize(0..corrupt.len());
        corrupt[at] ^= 1 << g.usize(0..8);
        let _ = wire::decode_frame::<UtsBag>(&corrupt);
    });
}

// ---------------------------------------------------------------------
// control-link codec hostility (crash tolerance rides on these frames:
// a corrupt Leave/Ack/Reconcile must error, never panic a survivor)
// ---------------------------------------------------------------------

fn random_str(g: &mut Gen, max: usize) -> String {
    let len = g.usize(0..max);
    (0..len).map(|_| (b'!' + (g.u64(0..90) as u8)) as char).collect()
}

/// A fully random telemetry snapshot: every gauge independently drawn
/// over the full u64 range, so a decode that swaps, drops, or sign-bends
/// any field cannot survive the round-trip comparison.
fn random_snapshot(g: &mut Gen) -> glb::glb::StatsSnapshot {
    glb::glb::StatsSnapshot {
        rank: g.u64(0..u64::MAX),
        seq: g.u64(0..u64::MAX),
        elapsed_ms: g.u64(0..u64::MAX),
        bag_depth: g.u64(0..u64::MAX),
        items: g.u64(0..u64::MAX),
        steals_out: g.u64(0..u64::MAX),
        steals_in: g.u64(0..u64::MAX),
        loot_sent: g.u64(0..u64::MAX),
        loot_recv: g.u64(0..u64::MAX),
        starvations: g.u64(0..u64::MAX),
        credit_pool: g.u64(0..u64::MAX),
        wire_tx: g.u64(0..u64::MAX),
        wire_rx: g.u64(0..u64::MAX),
        frames_tx: g.u64(0..u64::MAX),
        frames_rx: g.u64(0..u64::MAX),
        out_queue: g.u64(0..u64::MAX),
        last: g.bool(0.5),
    }
}

/// How many `Ctrl` variants [`random_ctrl`] covers — loop `0..CTRL_VARIANTS`
/// so every run exercises every frame type, including the
/// fault-tolerance frames (`Join`/`Leave`/`Ack`/`Reconcile`), the
/// telemetry frame (`Stats`), and the resident-fleet service frames
/// (`Submit`/`JobResult`/`Shutdown`).
const CTRL_VARIANTS: usize = 16;

/// A random `Ctrl` of the given variant index.
fn random_ctrl(g: &mut Gen, variant: usize) -> wire::Ctrl {
    use wire::Ctrl;
    match variant {
        0 => Ctrl::Register { rank: g.u64(0..u64::MAX), addr: random_str(g, 40) },
        1 => Ctrl::PeerMap {
            epoch: g.u64(0..u64::MAX),
            // Dead ranks keep their slot as an empty string.
            addrs: (0..g.usize(0..6))
                .map(|_| if g.bool(0.2) { String::new() } else { random_str(g, 24) })
                .collect(),
        },
        2 => Ctrl::Ready { rank: g.u64(0..u64::MAX) },
        3 => Ctrl::Go,
        4 => Ctrl::Deposit { job: g.u64(0..u64::MAX), atoms: g.u64(0..u64::MAX) },
        5 => Ctrl::Replenish { job: g.u64(0..u64::MAX), want: g.u64(0..u64::MAX) },
        6 => Ctrl::Grant { job: g.u64(0..u64::MAX), atoms: g.u64(0..u64::MAX) },
        7 => Ctrl::Result {
            job: g.u64(0..u64::MAX),
            bytes: (0..g.usize(0..64)).map(|_| g.u64(0..256) as u8).collect(),
        },
        8 => Ctrl::Join {
            epoch: g.u64(0..u64::MAX),
            rank: g.u64(0..u64::MAX),
            addr: random_str(g, 40),
        },
        9 => Ctrl::Leave { epoch: g.u64(0..u64::MAX), rank: g.u64(0..u64::MAX) },
        10 => Ctrl::Ack {
            rank: g.u64(0..u64::MAX),
            result: (0..g.usize(0..64)).map(|_| g.u64(0..256) as u8).collect(),
            acked: (0..g.usize(0..8))
                .map(|_| (g.u64(0..u64::MAX), g.u64(0..u64::MAX)))
                .collect(),
        },
        11 => Ctrl::Reconcile {
            rank: g.u64(0..u64::MAX),
            sent: g.u64(0..u64::MAX),
            received: g.u64(0..u64::MAX),
        },
        12 => Ctrl::Stats(random_snapshot(g)),
        13 => Ctrl::Submit {
            job: g.u64(0..u64::MAX),
            spec: random_str(g, 64),
            bag: (0..g.usize(0..96)).map(|_| g.u64(0..256) as u8).collect(),
        },
        14 => Ctrl::JobResult {
            job: g.u64(0..u64::MAX),
            bytes: (0..g.usize(0..64)).map(|_| g.u64(0..256) as u8).collect(),
        },
        _ => Ctrl::Shutdown,
    }
}

#[test]
fn prop_stats_frame_total_decode() {
    // The telemetry frame rides the same control links as the
    // termination-credit protocol; a malformed one must never take the
    // reactor down. Round-trip over the full gauge range, then every
    // strict prefix (a peer dying mid-write) errors cleanly, and a
    // trailing byte is rejected rather than silently carried.
    check_cases("stats-frame", 200, |g: &mut Gen| {
        let c = wire::Ctrl::Stats(random_snapshot(g));
        let body = c.to_body();
        assert_eq!(wire::Ctrl::decode(&body).expect("decode own encoding"), c);
        for cut in 0..body.len() {
            assert!(wire::Ctrl::decode(&body[..cut]).is_err(), "cut {cut}");
        }
        let mut long = body.clone();
        long.push(g.u64(0..256) as u8);
        assert!(wire::Ctrl::decode(&long).is_err(), "trailing byte");
    });
}

#[test]
fn prop_ctrl_roundtrip_every_variant() {
    check_cases("ctrl-roundtrip", 200, |g: &mut Gen| {
        for variant in 0..CTRL_VARIANTS {
            let c = random_ctrl(g, variant);
            let back = wire::Ctrl::decode(&c.to_body()).expect("decode own encoding");
            assert_eq!(back, c);
        }
    });
}

#[test]
fn prop_ctrl_hostile_bytes_error_not_panic() {
    check_cases("ctrl-hostility", 60, |g: &mut Gen| {
        for variant in 0..CTRL_VARIANTS {
            let body = random_ctrl(g, variant).to_body();
            // Every strict prefix is a clean error (a survivor reading a
            // dying peer's half-written frame must not panic or misread).
            for cut in 0..body.len() {
                assert!(wire::Ctrl::decode(&body[..cut]).is_err(), "variant {variant} cut {cut}");
            }
            // Trailing garbage is rejected, not silently ignored.
            let mut long = body.clone();
            long.push(g.u64(0..256) as u8);
            assert!(wire::Ctrl::decode(&long).is_err(), "variant {variant} trailing byte");
            // A flipped bit may decode to something else or error — never
            // panic (string fields may go non-utf8, counts may explode).
            let mut corrupt = body.clone();
            let at = g.usize(0..corrupt.len());
            corrupt[at] ^= 1 << g.usize(0..8);
            let _ = wire::Ctrl::decode(&corrupt);
        }
        // Pure noise must also decode totally (Ok or Err, no panic).
        let noise: Vec<u8> = (0..g.usize(0..64)).map(|_| g.u64(0..256) as u8).collect();
        let _ = wire::Ctrl::decode(&noise);
    });
}

// ---------------------------------------------------------------------
// pooled / into-buffer codec paths (the reactor's zero-copy data plane
// must be bit-identical to the allocate-per-frame encoders it replaced)
// ---------------------------------------------------------------------

#[test]
fn prop_pooled_encode_matches_allocating_encode_byte_for_byte() {
    use glb::glb::wire::BufferPool;
    let pool = BufferPool::new();
    check_cases("pooled-encode-identity", 200, |g: &mut Gen| {
        // Data frames, every Msg variant: encode_data_frame_into on a
        // recycled pool buffer vs the allocating body + frame() pair.
        let to = g.usize(0..1 << 20);
        let job = g.u64(0..u64::MAX);
        let bag = random_uts_bag(g);
        let msg = random_msg(g, bag);
        let old = wire::frame(wire::encode_data_frame_body(to, job, &msg));
        let mut buf = pool.get();
        let body_len = wire::encode_data_frame_into(to, job, &msg, &mut buf);
        assert_eq!(buf, old, "pooled data frame must be bit-identical");
        assert_eq!(body_len + wire::FRAME_LEN_BYTES, old.len());
        // Recycle and re-encode a different message: a dirty recycled
        // buffer must not leak prior bytes into the next frame.
        pool.put(buf);
        let bag2 = random_uts_bag(g);
        let msg2 = random_msg(g, bag2);
        let old2 = wire::frame(wire::encode_data_frame_body(to, job, &msg2));
        let mut buf2 = pool.get();
        wire::encode_data_frame_into(to, job, &msg2, &mut buf2);
        assert_eq!(buf2, old2, "recycled buffer must encode identically");
        pool.put(buf2);
        // Control frames, every Ctrl variant.
        for variant in 0..CTRL_VARIANTS {
            let c = random_ctrl(g, variant);
            let old = wire::frame(c.to_body());
            let mut buf = pool.get();
            let body_len = wire::encode_ctrl_frame_into(&c, &mut buf);
            assert_eq!(buf, old, "pooled ctrl frame must be bit-identical");
            assert_eq!(body_len + wire::FRAME_LEN_BYTES, old.len());
            pool.put(buf);
        }
    });
}

#[test]
fn prop_frame_assembler_decodes_any_split_points() {
    use glb::glb::wire::FrameAssembler;
    check_cases("assembler-split-fuzz", 150, |g: &mut Gen| {
        // A batched stream: several frames back to back, as the reactor's
        // writev coalescing would put them on the wire.
        let count = g.usize(1..8);
        let mut msgs = Vec::new();
        let mut stream = Vec::new();
        for _ in 0..count {
            let to = g.usize(0..1 << 20);
            let job = g.u64(0..u64::MAX);
            let bag = random_uts_bag(g);
            let msg = random_msg(g, bag);
            wire::encode_data_frame_into(to, job, &msg, &mut stream);
            msgs.push((to, job, msg));
        }
        // Feed it in arbitrary chunks (1..=17 bytes, including splits
        // inside length prefixes) and require the exact frame sequence.
        let mut asm = FrameAssembler::new(wire::MAX_FRAME_BYTES);
        let mut got = Vec::new();
        let mut off = 0;
        while off < stream.len() {
            let n = g.usize(1..18).min(stream.len() - off);
            asm.feed(&stream[off..off + n]);
            off += n;
            while let Some(body) = asm.next_frame().expect("well-formed stream") {
                got.push(wire::decode_data_frame_body::<UtsBag>(body).expect("decode frame"));
            }
        }
        assert_eq!(got, msgs, "split points must not change the decoded sequence");
        assert_eq!(asm.buffered(), 0, "no bytes may linger after the last frame");
    });
}

#[test]
fn prop_wire_bytes_pin_sim_accounting_to_codec() {
    // The simulator charges `Msg::wire_bytes` per message; the socket
    // transport sends `wire::encode_frame`. For bag-less messages the two
    // must agree to the byte; for loot the codec adds exactly the bag
    // count word on top of the per-entry payload.
    check_cases("wire-bytes-vs-codec", 200, |g: &mut Gen| {
        let entries = |b: &UtsBag| b.nodes().len();
        let bag = random_uts_bag(g);
        let msg = random_msg(g, bag);
        let encoded = wire::encode_frame(&msg).len();
        // The mesh data frame adds exactly the destination and job-epoch
        // prefix words the simulator charges on cross-node sends.
        let framed = wire::frame(wire::encode_data_frame_body(3, 0, &msg)).len();
        assert_eq!(framed, encoded + wire::DATA_ROUTE_BYTES + wire::DATA_JOB_BYTES);
        match &msg {
            Msg::Loot { bag: Some(b), .. } => {
                assert_eq!(
                    encoded,
                    wire::ENVELOPE_BYTES
                        + wire::BAG_LEN_BYTES
                        + UtsBag::WIRE_BYTES_PER_NODE * b.nodes().len()
                );
                assert_eq!(
                    encoded,
                    msg.wire_bytes(UtsBag::WIRE_BYTES_PER_NODE, entries) + wire::BAG_LEN_BYTES
                );
            }
            _ => assert_eq!(encoded, msg.wire_bytes(UtsBag::WIRE_BYTES_PER_NODE, entries)),
        }
    });
}

#[test]
fn prop_lifeline_graph_connected_bounded_degree() {
    check_cases("lifeline-topology", 120, |g: &mut Gen| {
        let p = g.usize(2..120);
        let l = g.usize(2..34);
        let z = g.usize(1..5);
        // The library raises z to cover all places (connectivity
        // guarantee), so the degree bound is against the effective z.
        let z_eff = z.max(glb::glb::params::derive_z(p, l));
        // Out-degree bound.
        for place in 0..p {
            let lg = LifelineGraph::new(place, p, l, z);
            assert!(lg.outgoing.len() <= z_eff);
            assert!(!lg.outgoing.contains(&place));
            assert!(lg.outgoing.iter().all(|&b| b < p));
        }
        // Connectivity from place 0 over the *undirected closure* is not
        // enough — work flows along directed edges, so check directed
        // reachability from every source via BFS (small P keeps it cheap).
        let adj: Vec<Vec<usize>> =
            (0..p).map(|v| LifelineGraph::new(v, p, l, z).outgoing.clone()).collect();
        let start = g.usize(0..p);
        let mut seen = HashSet::from([start]);
        let mut q = VecDeque::from([start]);
        while let Some(v) = q.pop_front() {
            for &w in &adj[v] {
                if seen.insert(w) {
                    q.push_back(w);
                }
            }
        }
        assert_eq!(seen.len(), p, "P={p} l={l} z={z}: not strongly reachable from {start}");
    });
}

#[test]
fn prop_sim_uts_correct_for_random_configs() {
    // The big one: random place counts, granularities, policies, arches
    // and seeds — the count must always equal the sequential count and
    // the ledger must balance (checked inside the sim via debug_assert).
    let archs: [&ArchProfile; 3] = [&POWER775, &BGQ, &K];
    check_cases("sim-uts-correctness", 40, |g: &mut Gen| {
        let p = g.usize(1..80);
        let d = g.usize(4..8) as u32;
        let up = UtsParams { b0: 4.0, seed: 19, max_depth: d };
        let expect = sequential_count(&up);
        let policy = if g.bool(0.25) {
            StealPolicy::RandomOnly { rounds: g.usize(1..4) }
        } else {
            StealPolicy::Lifeline
        };
        let params = GlbParams::default()
            .with_n(g.usize(1..600))
            .with_w(g.usize(0..4))
            .with_l(g.usize(2..8))
            .with_seed(g.u64(0..1 << 48))
            .with_policy(policy);
        let arch = *g.choose(&archs);
        let cfg = GlbConfig::new(p, params);
        let (out, _) = run_sim(
            &cfg,
            arch,
            CostModel::new(g.f64() * 400.0 + 10.0, g.u64(0..200), 32),
            |_, _| UtsQueue::new(up),
            |q| q.init_root(),
            &SumReducer,
        );
        assert_eq!(out.result, expect, "p={p} d={d} params={params:?}");
    });
}

#[test]
fn prop_sim_replay_identical() {
    check_cases("sim-replay", 15, |g: &mut Gen| {
        let p = g.usize(2..64);
        let seed = g.u64(0..1 << 32);
        let up = UtsParams { b0: 4.0, seed: 19, max_depth: 6 };
        let params = GlbParams::default().with_n(g.usize(8..128)).with_seed(seed);
        let cost = CostModel::new(100.0, 50, 32);
        let run = |_: ()| {
            let cfg = GlbConfig::new(p, params);
            run_sim(&cfg, &BGQ, cost, |_, _| UtsQueue::new(up), |q| q.init_root(), &SumReducer)
        };
        let (a, ra) = run(());
        let (b, rb) = run(());
        assert_eq!(a.elapsed_ns, b.elapsed_ns);
        assert_eq!(ra.events, rb.events);
    });
}

#[test]
fn prop_thread_runtime_uts_random_configs() {
    // Real-concurrency version (fewer cases: threads are slow to spawn).
    check_cases("threads-uts-correctness", 12, |g: &mut Gen| {
        let p = g.usize(1..9);
        let d = g.usize(4..7) as u32;
        let up = UtsParams { b0: 4.0, seed: 19, max_depth: d };
        let expect = sequential_count(&up);
        let params = GlbParams::default()
            .with_n(g.usize(1..300))
            .with_w(g.usize(0..3))
            .with_l(g.usize(2..5))
            .with_seed(g.u64(0..1 << 32));
        let cfg = GlbConfig::new(p, params);
        let out = glb::place::run_threads(
            &cfg,
            |_, _| UtsQueue::new(up),
            |q| q.init_root(),
            &SumReducer,
        );
        assert_eq!(out.result, expect, "p={p} d={d}");
    });
}

#[test]
fn prop_stats_invariants_hold() {
    check_cases("stats-invariants", 25, |g: &mut Gen| {
        let p = g.usize(2..48);
        let up = UtsParams { b0: 4.0, seed: 19, max_depth: 6 };
        let params = GlbParams::default().with_n(g.usize(4..128)).with_seed(g.u64(0..1 << 40));
        let cfg = GlbConfig::new(p, params);
        let (out, rep) = run_sim(
            &cfg,
            &K,
            CostModel::new(120.0, 60, 32),
            |_, _| UtsQueue::new(up),
            |q| q.init_root(),
            &SumReducer,
        );
        let t = out.log.total();
        assert_eq!(t.loot_bags_sent, t.loot_bags_received);
        assert_eq!(t.loot_items_sent, t.loot_items_received);
        assert!(t.units >= out.result - 1, "work units track nodes");
        assert!(rep.messages >= t.loot_bags_sent);
        // Every place's stats are internally consistent.
        for s in &out.log.per_place {
            assert!(
                s.random_steals_perpetrated <= s.random_steals_sent,
                "cannot succeed more often than trying"
            );
            assert!(s.lifeline_steals_perpetrated <= s.lifeline_steals_sent + 64,
                "lifeline pushes may exceed sends only via re-registration; wildly off means a bug");
        }
    });
}

#[test]
fn prop_sim_survives_message_jitter() {
    // Fault injection: adversarial per-message delays reorder deliveries
    // across senders. Correctness (count + termination + ledger) must be
    // timing-independent.
    check_cases("sim-jitter", 25, |g: &mut Gen| {
        let p = g.usize(2..48);
        let jitter = g.u64(1..2_000_000); // up to 2ms of reordering
        let up = UtsParams { b0: 4.0, seed: 19, max_depth: 6 };
        let expect = sequential_count(&up);
        let params = GlbParams::default().with_n(g.usize(1..200)).with_seed(g.u64(0..1 << 40));
        let cfg = GlbConfig::new(p, params);
        let (out, _) = glb::sim::run_sim_jitter(
            &cfg,
            &BGQ,
            CostModel::new(100.0, 50, 32),
            jitter,
            |_, _| UtsQueue::new(up),
            |q| q.init_root(),
            &SumReducer,
        );
        assert_eq!(out.result, expect, "p={p} jitter={jitter}");
    });
}

#[test]
fn prop_hierarchical_topology_agrees_with_flat() {
    // The tentpole invariant of the topology layer: grouping workers into
    // nodes (any wpn, ragged last node included) changes who moves work,
    // never what is computed. Both substrates' ledgers are debug-asserted
    // to balance at termination inside the runtimes.
    check_cases("hier-vs-flat", 30, |g: &mut Gen| {
        let p = g.usize(2..48);
        let wpn = g.usize(2..9);
        let d = g.usize(4..7) as u32;
        let up = UtsParams { b0: 4.0, seed: 19, max_depth: d };
        let expect = sequential_count(&up);
        let base = GlbParams::default()
            .with_n(g.usize(1..300))
            .with_w(g.usize(0..3))
            .with_l(g.usize(2..8))
            .with_seed(g.u64(0..1 << 40));
        let cost = CostModel::new(g.f64() * 300.0 + 10.0, g.u64(0..150), 32);
        let run = |params: GlbParams| {
            let cfg = GlbConfig::new(p, params);
            run_sim(&cfg, &BGQ, cost, |_, _| UtsQueue::new(up), |q| q.init_root(), &SumReducer)
        };
        let (flat, _) = run(base);
        let (hier, _) = run(base.with_workers_per_node(wpn));
        assert_eq!(flat.result, expect, "flat p={p}");
        assert_eq!(hier.result, expect, "hier p={p} wpn={wpn}");
        // Flat runs must never touch the hierarchical machinery.
        let ft = flat.log.total();
        assert_eq!(ft.node_donations + ft.node_takes + ft.node_loot_sent, 0);
        // Hierarchical node-bag accounting balances at termination.
        let ht = hier.log.total();
        assert_eq!(ht.node_donations, ht.node_takes, "p={p} wpn={wpn}: parked shards reclaimed");
        assert_eq!(ht.node_loot_sent, ht.node_loot_received, "local pushes all land");
        assert_eq!(ht.loot_bags_sent, ht.loot_bags_received, "no loot lost under hierarchy");
    });
}

#[test]
fn prop_hierarchical_threads_agree_with_flat() {
    // Real-concurrency version: node bags are shared across OS threads,
    // so this exercises the Mutex paths and the AtomicLedger balance
    // (debug-asserted zero at termination inside run_threads).
    check_cases("hier-threads", 10, |g: &mut Gen| {
        let p = g.usize(2..9);
        let wpn = g.usize(2..5);
        let d = g.usize(4..7) as u32;
        let up = UtsParams { b0: 4.0, seed: 19, max_depth: d };
        let expect = sequential_count(&up);
        let params = GlbParams::default()
            .with_n(g.usize(1..200))
            .with_l(g.usize(2..5))
            .with_seed(g.u64(0..1 << 32))
            .with_workers_per_node(wpn);
        let cfg = GlbConfig::new(p, params);
        let out = glb::place::run_threads(
            &cfg,
            |_, _| UtsQueue::new(up),
            |q| q.init_root(),
            &SumReducer,
        );
        assert_eq!(out.result, expect, "p={p} wpn={wpn} d={d}");
        let t = out.log.total();
        assert_eq!(t.node_donations, t.node_takes, "p={p} wpn={wpn}");
        assert_eq!(t.node_loot_sent, t.node_loot_received, "p={p} wpn={wpn}");
    });
}

// ---------------------------------------------------------------------
// credit-based distributed termination
// ---------------------------------------------------------------------

#[test]
fn prop_credit_conserved_under_reorder() {
    // The socket fleet's termination detector rests on one invariant:
    // every credit atom ever minted is either recovered at the root, in
    // some rank's pool, attached to an in-flight loot message, or inside
    // an undelivered deposit — and the root fires exactly when the first
    // bucket holds everything. This drives N rank ledgers through random
    // acquire/release/loot-send/loot-receive schedules with deposits
    // delivered arbitrarily late and out of order, checking conservation
    // after every step and quiescence exactly once at the end. Tiny
    // initial grants force the synchronous replenish path too.
    use glb::glb::termination::{CreditHome, CreditLedger, CreditRoot, Ledger};
    use std::sync::{Arc, Mutex};

    /// Models the control link: deposits queue with unbounded delay (the
    /// case delivers them in random order); replenish stays synchronous,
    /// as in the real transport.
    struct DelayedHome {
        root: Arc<CreditRoot>,
        pending: Arc<Mutex<Vec<u64>>>,
    }

    impl CreditHome for DelayedHome {
        fn deposit(&self, atoms: u64) {
            self.pending.lock().unwrap().push(atoms);
        }
        fn replenish(&self, want: u64) -> u64 {
            self.root.mint(want)
        }
    }

    check_cases("credit-conservation", 150, |g: &mut Gen| {
        let ranks = g.usize(2..8);
        let root = CreditRoot::new();
        let pending: Arc<Mutex<Vec<u64>>> = Arc::new(Mutex::new(Vec::new()));
        let ledgers: Vec<_> = (0..ranks)
            .map(|_| {
                // 1..4 atoms: exports exhaust pools fast, exercising the
                // replenish (mint) path in most cases.
                let grant = g.u64(1..5);
                root.grant(grant);
                let home = DelayedHome { root: root.clone(), pending: pending.clone() };
                CreditLedger::new(Arc::new(home), grant)
            })
            .collect();
        root.arm();
        // Every rank "kicks" once, as the runtimes do at the barrier.
        for l in &ledgers {
            l.incr();
        }
        let mut inflight: Vec<u64> = Vec::new();

        let conserved = |inflight: &[u64]| {
            let (total, recovered) = root.totals();
            let pools: u64 = ledgers.iter().map(|l| l.pool()).sum();
            let queued: u64 = pending.lock().unwrap().iter().sum();
            let flying: u64 = inflight.iter().sum();
            assert_eq!(
                total,
                recovered + pools + queued + flying,
                "atoms created {total} != recovered {recovered} + pools {pools} \
                 + queued {queued} + in-flight {flying}"
            );
        };

        for _ in 0..g.usize(10..200) {
            let r = g.usize(0..ranks);
            match g.usize(0..5) {
                // Acquire another token (split work / park a shard).
                0 => {
                    if ledgers[r].pool() >= 1 && ledgers[r].tokens() >= 1 {
                        ledgers[r].incr();
                    }
                }
                // Release a token; hitting zero deposits the whole pool.
                1 => {
                    if ledgers[r].tokens() >= 1 {
                        assert!(!ledgers[r].decr(), "distributed ledgers never observe zero");
                    }
                }
                // Send loot: message token + exported credit in flight.
                2 => {
                    if ledgers[r].tokens() >= 1 {
                        ledgers[r].incr();
                        let credit = ledgers[r].export_credit();
                        assert!(credit >= 1, "loot must carry credit");
                        inflight.push(credit);
                    }
                }
                // Receive loot at a random rank: import, then either
                // destroy the token (active thief) or adopt it (idle).
                3 => {
                    if !inflight.is_empty() {
                        let at = g.usize(0..inflight.len());
                        let credit = inflight.swap_remove(at);
                        let to = g.usize(0..ranks);
                        ledgers[to].import_credit(credit);
                        if g.bool(0.5) {
                            ledgers[to].decr();
                        }
                    }
                }
                // Deliver one queued deposit to the root — arbitrarily
                // late, in arbitrary order.
                _ => {
                    let delivered = {
                        let mut q = pending.lock().unwrap();
                        if q.is_empty() {
                            None
                        } else {
                            let at = g.usize(0..q.len());
                            Some(q.swap_remove(at))
                        }
                    };
                    if let Some(atoms) = delivered {
                        root.deposit(atoms);
                    }
                }
            }
            conserved(&inflight);
            let tokens: i64 = ledgers.iter().map(|l| l.tokens()).sum();
            if root.quiescent() {
                // Detection is never early: the fleet must be genuinely
                // done the instant the root fires.
                assert_eq!(tokens, 0, "fired while tokens were held");
                assert!(inflight.is_empty(), "fired while loot was in flight");
                assert!(pending.lock().unwrap().is_empty(), "fired before all deposits");
                return;
            }
            if tokens > 0 {
                assert!(!root.quiescent(), "live fleet must not be quiescent");
            }
        }

        // Drain: land all loot, idle every rank, deliver every deposit.
        while let Some(credit) = inflight.pop() {
            let to = g.usize(0..ranks);
            ledgers[to].import_credit(credit);
            ledgers[to].decr();
        }
        for l in &ledgers {
            while l.tokens() > 0 {
                l.decr();
            }
        }
        loop {
            let delivered = {
                let mut q = pending.lock().unwrap();
                q.pop()
            };
            match delivered {
                Some(atoms) => root.deposit(atoms),
                None => break,
            }
        }
        conserved(&inflight);
        assert!(root.quiescent(), "a fully drained fleet must be detected");
        let (total, recovered) = root.totals();
        assert_eq!(total, recovered, "every atom recovered at quiescence");
        assert!(ledgers.iter().all(|l| l.pool() == 0), "idle ranks hold no credit");
    });
}

#[test]
fn prop_credit_conserved_under_rank_death() {
    // Crash tolerance's accounting core: when a rank dies, the root
    // solves `granted − deposited + Σsent − Σreceived` from the
    // survivors' books and reclaims exactly the atoms that died with the
    // rank — its pool, deposits written but never landed, and loot it
    // exported that nobody received. This model drives random schedules
    // to a random crash point, kills one non-root rank (its queued
    // deposits and in-flight exports each land or vanish at random, like
    // a severed TCP link), checks the reconcile formula against the
    // ground-truth loss, reclaims, and then runs the survivors to
    // quiescence — `recovered == total` must still be exact.
    use glb::glb::termination::{CreditHome, CreditLedger, CreditRoot, Ledger};
    use std::sync::{Arc, Mutex};

    struct BookedHome {
        rank: usize,
        root: Arc<CreditRoot>,
        pending: Arc<Mutex<Vec<(usize, u64)>>>,
        granted: Arc<Mutex<Vec<u64>>>,
    }

    impl CreditHome for BookedHome {
        fn deposit(&self, atoms: u64) {
            self.pending.lock().unwrap().push((self.rank, atoms));
        }
        fn replenish(&self, want: u64) -> u64 {
            let got = self.root.mint(want);
            self.granted.lock().unwrap()[self.rank] += got;
            got
        }
    }

    check_cases("credit-rank-death", 150, |g: &mut Gen| {
        let ranks = g.usize(3..8);
        let root = CreditRoot::new();
        let pending: Arc<Mutex<Vec<(usize, u64)>>> = Arc::new(Mutex::new(Vec::new()));
        let granted = Arc::new(Mutex::new(vec![0u64; ranks]));
        let ledgers: Vec<_> = (0..ranks)
            .map(|r| {
                let grant = g.u64(1..5);
                root.grant(grant);
                granted.lock().unwrap()[r] = grant;
                let home = BookedHome {
                    rank: r,
                    root: root.clone(),
                    pending: pending.clone(),
                    granted: granted.clone(),
                };
                CreditLedger::new(Arc::new(home), grant)
            })
            .collect();
        root.arm();
        for l in &ledgers {
            l.incr();
        }

        let mut alive = vec![true; ranks];
        // Root-received deposits per rank (the root's `deposited` books).
        let mut deposited = vec![0u64; ranks];
        // delivered[s][d]: atoms of s's loot that d merged (i.e. acked —
        // in-flight retained entries are re-imported at death instead).
        let mut delivered = vec![vec![0u64; ranks]; ranks];
        let mut inflight: Vec<(usize, usize, u64)> = Vec::new();

        let conserved = |alive: &[bool], inflight: &[(usize, usize, u64)]| {
            let (total, recovered) = root.totals();
            let pools: u64 =
                ledgers.iter().zip(alive).filter(|(_, a)| **a).map(|(l, _)| l.pool()).sum();
            let queued: u64 = pending.lock().unwrap().iter().map(|&(_, a)| a).sum();
            let flying: u64 = inflight.iter().map(|&(_, _, c)| c).sum();
            assert_eq!(total, recovered + pools + queued + flying);
        };

        let steps = g.usize(20..120);
        let death_at = g.usize(0..steps / 2);
        let mut death_done = false;
        for step in 0..steps {
            if step == death_at && !death_done {
                death_done = true;
                let d = g.usize(1..ranks);
                alive[d] = false;
                let mut lost = 0u64;
                // The dead rank's written deposits: each either landed
                // before the root's reader saw EOF, or died in a buffer.
                let drained: Vec<(usize, u64)> = {
                    let mut q = pending.lock().unwrap();
                    let (dead, keep) = q.drain(..).partition(|&(r, _)| r == d);
                    *q = keep;
                    dead
                };
                for (_, atoms) in drained {
                    if g.bool(0.5) {
                        root.deposit(atoms);
                        deposited[d] += atoms;
                    } else {
                        lost += atoms;
                    }
                }
                // In-flight loot: exports *to* the dead rank are retained
                // by their senders and re-imported (the message token is
                // consumed as the self-merge completes); exports *from*
                // it race the link teardown.
                let mut keep = Vec::new();
                for (from, to, credit) in inflight.drain(..) {
                    if to == d {
                        ledgers[from].import_credit(credit);
                        ledgers[from].decr();
                    } else if from == d {
                        if g.bool(0.5) {
                            ledgers[to].import_credit(credit);
                            delivered[d][to] += credit;
                        } else {
                            lost += credit;
                        }
                    } else {
                        keep.push((from, to, credit));
                    }
                }
                inflight = keep;
                // The survivors' books must solve to exactly the atoms
                // that actually vanished.
                let sent_to_dead: u64 = (0..ranks).map(|s| delivered[s][d]).sum();
                let recv_from_dead: u64 = (0..ranks).map(|s| delivered[d][s]).sum();
                let solved = granted.lock().unwrap()[d] as i128 - deposited[d] as i128
                    + sent_to_dead as i128
                    - recv_from_dead as i128;
                let truth = (ledgers[d].pool() + lost) as i128;
                assert_eq!(solved, truth, "reconcile books disagree with the actual loss");
                assert!(solved >= 0);
                root.reclaim(solved as u64);
                conserved(&alive, &inflight);
                continue;
            }
            let r = loop {
                let r = g.usize(0..ranks);
                if alive[r] {
                    break r;
                }
            };
            match g.usize(0..5) {
                0 => {
                    if ledgers[r].pool() >= 1 && ledgers[r].tokens() >= 1 {
                        ledgers[r].incr();
                    }
                }
                1 => {
                    if ledgers[r].tokens() >= 1 {
                        ledgers[r].decr();
                    }
                }
                2 => {
                    if ledgers[r].tokens() >= 1 {
                        let to = loop {
                            let t = g.usize(0..ranks);
                            if t != r && alive[t] {
                                break t;
                            }
                        };
                        ledgers[r].incr();
                        let credit = ledgers[r].export_credit();
                        assert!(credit >= 1, "loot must carry credit");
                        inflight.push((r, to, credit));
                    }
                }
                3 => {
                    if !inflight.is_empty() {
                        let at = g.usize(0..inflight.len());
                        let (from, to, credit) = inflight.swap_remove(at);
                        ledgers[to].import_credit(credit);
                        delivered[from][to] += credit;
                        if g.bool(0.5) {
                            ledgers[to].decr();
                        }
                    }
                }
                _ => {
                    let landed = {
                        let mut q = pending.lock().unwrap();
                        if q.is_empty() {
                            None
                        } else {
                            let at = g.usize(0..q.len());
                            Some(q.swap_remove(at))
                        }
                    };
                    if let Some((rank, atoms)) = landed {
                        root.deposit(atoms);
                        deposited[rank] += atoms;
                    }
                }
            }
            conserved(&alive, &inflight);
            let tokens: i64 = ledgers
                .iter()
                .zip(&alive)
                .filter(|(_, a)| **a)
                .map(|(l, _)| l.tokens())
                .sum();
            if root.quiescent() {
                assert_eq!(tokens, 0, "fired while survivors held tokens");
                assert!(inflight.is_empty(), "fired while loot was in flight");
                assert!(pending.lock().unwrap().is_empty(), "fired before all deposits");
                return;
            }
        }

        // Drain the survivors: land all loot, idle everyone, deliver
        // every deposit. Recovery must leave quiescence reachable *and
        // exact* — reclaiming a wrong count would fire early or never.
        while let Some((from, to, credit)) = inflight.pop() {
            ledgers[to].import_credit(credit);
            delivered[from][to] += credit;
            ledgers[to].decr();
        }
        for (l, a) in ledgers.iter().zip(&alive) {
            if *a {
                while l.tokens() > 0 {
                    l.decr();
                }
            }
        }
        loop {
            let landed = {
                let mut q = pending.lock().unwrap();
                q.pop()
            };
            match landed {
                Some((rank, atoms)) => {
                    root.deposit(atoms);
                    deposited[rank] += atoms;
                }
                None => break,
            }
        }
        conserved(&alive, &inflight);
        assert!(root.quiescent(), "a drained fleet with one absorbed death must be detected");
        let (total, recovered) = root.totals();
        assert_eq!(total, recovered, "every atom recovered, dead rank's by reclaim");
    });
}

#[test]
fn prop_autotuned_params_always_valid_and_correct() {
    use glb::glb::autotune::{autotune, WorkloadProfile};
    check_cases("autotune-validity", 30, |g: &mut Gen| {
        let p = g.usize(1..2000);
        let profile = WorkloadProfile::new(g.f64() * 10_000.0 + 1.0, g.f64());
        let params = autotune(p, profile);
        params.validate().expect("autotuned params must validate");
        // Spot-run a small configuration.
        if p <= 32 {
            let up = UtsParams { b0: 4.0, seed: 19, max_depth: 5 };
            let cfg = GlbConfig::new(p, params);
            let (out, _) = run_sim(
                &cfg,
                &POWER775,
                CostModel::new(100.0, 50, 32),
                |_, _| UtsQueue::new(up),
                |q| q.init_root(),
                &SumReducer,
            );
            assert_eq!(out.result, sequential_count(&up));
        }
    });
}
