//! PJRT runtime integration: load the AOT artifacts, execute batched
//! Brandes from rust, and cross-check against the sparse CPU engine.
//!
//! Requires `make artifacts` (skipped with a clear message otherwise).

use std::path::PathBuf;
use std::sync::Arc;

use glb::apps::bc::{sequential_bc, BcQueue, Graph, RmatParams};
use glb::glb::task_queue::VecSumReducer;
use glb::glb::{GlbConfig, GlbParams};
use glb::place::run_threads;
use glb::runtime::{DeviceService, Engine, Manifest};

fn artifact_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.txt").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: no artifacts/ — run `make artifacts` first");
        None
    }
}

/// A graph sized for the n=64 artifact: R-MAT scale 6.
fn graph64() -> Arc<Graph> {
    Arc::new(Graph::rmat(RmatParams { scale: 6, ..Default::default() }))
}

#[test]
fn manifest_lists_generated_artifacts() {
    let Some(dir) = artifact_dir() else { return };
    let m = Manifest::load(&dir).unwrap();
    assert!(m.find_brandes(64, None).is_some(), "n=64 artifact expected");
    assert!(m.find_brandes(256, None).is_some(), "n=256 artifact expected");
    assert!(m.of_kind("uts_expand").count() >= 1);
}

#[test]
fn engine_executes_batched_brandes_and_matches_sparse() {
    let Some(dir) = artifact_dir() else { return };
    let g = graph64();
    let mut eng = Engine::new(&dir).unwrap();
    let be = eng.brandes(&g.dense_adjacency(), g.n()).unwrap();
    assert_eq!(be.n, 64);

    // Full BC by batching all sources through the artifact.
    let mut bc = vec![0.0f64; g.n()];
    let mut edges = 0u64;
    let sources: Vec<u32> = (0..g.n() as u32).collect();
    for chunk in sources.chunks(be.s) {
        let out = eng.run_brandes(&be, chunk).unwrap();
        for (acc, x) in bc.iter_mut().zip(&out.bc) {
            *acc += *x as f64;
        }
        edges += out.edges;
    }

    let (want, want_edges) = sequential_bc(&g);
    for (i, (a, b)) in bc.iter().zip(&want).enumerate() {
        assert!(
            (a - b).abs() <= 1e-3 * (1.0 + b.abs()),
            "bc[{i}]: pjrt {a} vs sparse {b}"
        );
    }
    assert_eq!(edges, want_edges, "edge accounting must agree exactly");
}

#[test]
fn engine_pads_partial_batches() {
    let Some(dir) = artifact_dir() else { return };
    let g = graph64();
    let mut eng = Engine::new(&dir).unwrap();
    let be = eng.brandes(&g.dense_adjacency(), g.n()).unwrap();
    let full = eng.run_brandes(&be, &[0, 1, 2]).unwrap();
    let (a, ea) = {
        let o = eng.run_brandes(&be, &[0]).unwrap();
        (o.bc, o.edges)
    };
    let (b, eb) = {
        let o = eng.run_brandes(&be, &[1, 2]).unwrap();
        (o.bc, o.edges)
    };
    for i in 0..g.n() {
        let sum = a[i] + b[i];
        assert!((full.bc[i] - sum).abs() < 1e-3, "bc[{i}]: {} vs {}", full.bc[i], sum);
    }
    assert_eq!(full.edges, ea + eb);
    // Empty batch short-circuits.
    let empty = eng.run_brandes(&be, &[]).unwrap();
    assert_eq!(empty.edges, 0);
    assert!(empty.bc.iter().all(|&x| x == 0.0));
}

#[test]
fn device_service_drives_glb_dense_bc() {
    // The end-to-end L3->PJRT path: GLB workers over threads, each
    // draining vertex intervals by calling the device service.
    let Some(dir) = artifact_dir() else { return };
    let g = graph64();
    let svc = DeviceService::start(&dir, g.dense_adjacency(), g.n()).unwrap();
    let handle = svc.handle();
    let n = g.n() as u32;
    let cfg = GlbConfig::new(3, GlbParams::default().with_n(8).with_l(2));
    let out = run_threads(
        &cfg,
        move |_, _| BcQueue::dense(handle.clone()),
        |q| q.assign(0, n),
        &VecSumReducer,
    );
    let (want, want_edges) = sequential_bc(&g);
    for (i, (a, b)) in out.result.iter().zip(&want).enumerate() {
        assert!((a - b).abs() <= 1e-3 * (1.0 + b.abs()), "bc[{i}]: {a} vs {b}");
    }
    let units: u64 = out.log.per_place.iter().map(|s| s.units).sum();
    assert_eq!(units, want_edges);
}

#[test]
fn uts_expand_artifact_loads_and_runs() {
    let Some(dir) = artifact_dir() else { return };
    let mut eng = Engine::new(&dir).unwrap();
    let entry = eng.manifest().of_kind("uts_expand").next().unwrap().clone();
    let b = entry.attr("b").unwrap() as usize;
    let exe = eng.load(&entry.file).unwrap();
    // Feed descriptor words; compare against the rust geometric law.
    let h: Vec<u32> = (0..b as u32).map(|i| i.wrapping_mul(2_654_435_761)).collect();
    let lit = xla::Literal::vec1(&h);
    let out = exe.execute::<xla::Literal>(&[lit]).unwrap()[0][0]
        .to_literal_sync()
        .unwrap()
        .to_tuple1()
        .unwrap();
    let kids = out.to_vec::<i32>().unwrap();
    assert_eq!(kids.len(), b);
    for (i, (&hash, &k)) in h.iter().zip(&kids).enumerate() {
        let u = (hash & 0x7FFF_FFFF) as f64 / (1u64 << 31) as f64;
        let want = glb::apps::uts::sha1rand::geometric_children(u, 4.0) as i32;
        // f32 kernel vs f64 rust: floor() boundaries may differ by 1 ULP
        // of probability; allow off-by-one per lane.
        assert!((k - want).abs() <= 1, "lane {i}: kernel {k} vs rust {want}");
    }
}
