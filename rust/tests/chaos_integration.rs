//! Chaos tests: SIGKILL a fleet rank at a chosen protocol phase and
//! check the survivors still produce the *exact* answer (ISSUE 6's
//! acceptance scenario). The kill is injected by `testkit::chaos` via
//! environment variables the launched ranks inherit; SIGKILL leaves no
//! time for goodbyes, so from the fleet's point of view the rank's
//! machine simply vanished.
//!
//! Process-spawning tests are `#[ignore]`d like the socket fleet tests;
//! CI runs them explicitly with `--ignored --test-threads=1`.

use std::path::PathBuf;
use std::process::Output;

use glb::apps::fib::fib;
use glb::apps::uts::{sequential_count, UtsParams};
use glb::launch::report::load_fleet_report;
use glb::testkit::{chaos, fleet};
use glb::util::json::Value;

/// The pinned acceptance workload: UTS depth 8 with the repo's fixed
/// tree parameters is exactly 41314 nodes — any lost or double-counted
/// loot after a crash shows up here as a wrong count, not a flake.
const UTS_DEPTH_8_NODES: u64 = 41314;

fn launch_with_chaos(
    launcher_args: &[&str],
    app_args: &[&str],
    die_point: &str,
    victim_rank: usize,
) -> Output {
    let bin = env!("CARGO_BIN_EXE_glb");
    let port = fleet::free_port();
    std::process::Command::new(bin)
        .arg("launch")
        .args(["--port", &port.to_string()])
        .args(launcher_args)
        .args(app_args)
        .env(chaos::ENV_DIE, die_point)
        .env(chaos::ENV_RANK, victim_rank.to_string())
        .output()
        .expect("run glb launch")
}

fn report_path(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("glb-chaos-{tag}-{}.json", std::process::id()))
}

fn assert_success(output: &Output) {
    assert!(
        output.status.success(),
        "glb launch failed ({}):\n--- stdout\n{}\n--- stderr\n{}",
        output.status,
        String::from_utf8_lossy(&output.stdout),
        String::from_utf8_lossy(&output.stderr),
    );
}

/// Load a fleet report and return (result, dead_ranks).
fn result_and_dead(path: &PathBuf) -> (u64, Vec<u64>) {
    let report = load_fleet_report(path).expect("fleet report parses");
    let result = report.get("result").and_then(Value::as_u64).expect("numeric result");
    let dead: Vec<u64> = report
        .get("dead_ranks")
        .and_then(Value::as_arr)
        .expect("dead_ranks array")
        .iter()
        .map(|v| v.as_u64().expect("dead rank is numeric"))
        .collect();
    (result, dead)
}

/// ISSUE 6's acceptance scenario: a 4-rank UTS fleet with
/// `--tolerate-failures 1` survives rank 2 being SIGKILLed right after
/// it puts a steal request on the wire, and still counts *exactly*
/// 41314 nodes — the retained-loot replay and credit reclaim must not
/// lose or duplicate a single subtree.
#[test]
#[ignore = "process fleet: run explicitly via `--ignored --test-threads=1` (see CI)"]
fn tolerant_fleet_survives_a_mid_steal_sigkill_exactly() {
    let up = UtsParams { b0: 4.0, seed: 19, max_depth: 8 };
    assert_eq!(sequential_count(&up), UTS_DEPTH_8_NODES, "pinned workload moved");

    let report = report_path("mid-steal");
    let out = launch_with_chaos(
        &["--np", "4", "--tolerate-failures", "1", "--report", report.to_str().unwrap()],
        &["uts", "--depth", "8"],
        chaos::MID_STEAL,
        2,
    );
    assert_success(&out);

    let (result, dead) = result_and_dead(&report);
    assert_eq!(result, UTS_DEPTH_8_NODES, "crash recovery must keep the count exact");
    assert_eq!(dead, vec![2], "the report must record the absorbed death");
    std::fs::remove_file(&report).ok();
}

/// The same kill without `--tolerate-failures` must fail the whole
/// fleet quickly and loudly — silent wrong answers are the one
/// unacceptable outcome.
#[test]
#[ignore = "process fleet: run explicitly via `--ignored --test-threads=1` (see CI)"]
fn untolerated_sigkill_still_fails_the_fleet_fast() {
    let t0 = std::time::Instant::now();
    let out = launch_with_chaos(&["--np", "4"], &["uts", "--depth", "8"], chaos::MID_STEAL, 2);
    let elapsed = t0.elapsed();
    assert!(
        !out.status.success(),
        "a rank death without --tolerate-failures must fail the launch:\n{}",
        String::from_utf8_lossy(&out.stdout),
    );
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("rank 2"), "failure must name the dead rank: {stderr}");
    assert!(
        elapsed < std::time::Duration::from_secs(60),
        "fail-fast took {elapsed:?} — the launcher waited out the deadline"
    );
}

/// Kill a rank at the idle wait (all credit deposited, empty bag). The
/// dead rank's last banked ack snapshot covers everything it computed,
/// so the gathered fib sum must still be exact.
#[test]
#[ignore = "process fleet: run explicitly via `--ignored --test-threads=1` (see CI)"]
fn tolerant_fleet_survives_a_while_idle_sigkill_exactly() {
    const N: u64 = 25;
    let report = report_path("while-idle");
    let out = launch_with_chaos(
        &["--np", "4", "--tolerate-failures", "1", "--report", report.to_str().unwrap()],
        &["fib", "--fib-n", "25"],
        chaos::WHILE_IDLE,
        2,
    );
    assert_success(&out);

    let (result, dead) = result_and_dead(&report);
    assert_eq!(result, fib(N), "crash recovery must keep the fib sum exact");
    assert_eq!(dead, vec![2]);
    std::fs::remove_file(&report).ok();
}

/// A tolerated death makes rank 0 broadcast `Leave` to every survivor;
/// the survivors' reactors must absorb it (peer queues closed, recovery
/// replay run) without wedging — the launch completes with the exact
/// count, and each survivor's report shows exactly one I/O thread: the
/// event-loop transport's O(workers)-not-O(peers) property, which a
/// leaked or respawned reactor thread would break.
#[test]
#[ignore = "process fleet: run explicitly via `--ignored --test-threads=1` (see CI)"]
fn reactor_tears_down_cleanly_after_a_leave() {
    let report = report_path("leave-teardown");
    let out = launch_with_chaos(
        &["--np", "4", "--tolerate-failures", "1", "--report", report.to_str().unwrap()],
        &["uts", "--depth", "8"],
        chaos::WHILE_IDLE,
        1,
    );
    assert_success(&out);

    let fleet = load_fleet_report(&report).expect("fleet report parses");
    assert_eq!(fleet.get("result").and_then(Value::as_u64), Some(UTS_DEPTH_8_NODES));
    let per_rank = fleet.get("per_rank").and_then(Value::as_arr).expect("per_rank array");
    assert_eq!(per_rank.len(), 3, "three survivors report");
    for r in per_rank {
        assert_eq!(
            r.get("io_threads").and_then(Value::as_u64),
            Some(1),
            "one reactor thread per surviving rank"
        );
    }
    std::fs::remove_file(&report).ok();
}

/// The steal-latency regression pin for the mark-leak fix: SIGKILL a
/// rank while a steal round-trip involving it is on the wire. The
/// stealer's mark for that round-trip can never be paired with a
/// reply; the `Leave` purge must drop it silently, so every survivor's
/// latency books hold `steal_samples <= steals it actually sent` — a
/// purged (or leaked-and-recycled) mark booked as a completed
/// round-trip breaks that bound, and stale pairings show up as a
/// latency/sample-count mismatch.
#[test]
#[ignore = "process fleet: run explicitly via `--ignored --test-threads=1` (see CI)"]
fn steal_latency_books_ignore_round_trips_the_victim_never_answered() {
    let report = report_path("mark-purge");
    let out = launch_with_chaos(
        &["--np", "4", "--tolerate-failures", "1", "--report", report.to_str().unwrap()],
        &["uts", "--depth", "8"],
        chaos::MID_STEAL,
        2,
    );
    assert_success(&out);

    let fleet = load_fleet_report(&report).expect("fleet report parses");
    assert_eq!(fleet.get("result").and_then(Value::as_u64), Some(UTS_DEPTH_8_NODES));
    let per_rank = fleet.get("per_rank").and_then(Value::as_arr).expect("per_rank array");
    assert_eq!(per_rank.len(), 3, "three survivors report");
    let mut survivor_samples = 0u64;
    for r in per_rank {
        let rank = r.get("rank").and_then(Value::as_u64).expect("rank id");
        let samples = r.get("steal_samples").and_then(Value::as_u64).expect("steal_samples");
        let latency = r.get("steal_latency_us").and_then(Value::as_f64).expect("steal_latency_us");
        let totals = r.get("log").and_then(|l| l.get("totals")).expect("rank totals");
        let sent = totals.get("random_steals_sent").and_then(Value::as_u64).unwrap_or(0)
            + totals.get("lifeline_steals_sent").and_then(Value::as_u64).unwrap_or(0);
        assert!(
            samples <= sent,
            "rank {rank}: {samples} latency samples from only {sent} sent steals — \
             an unanswered round-trip was booked as completed"
        );
        assert_eq!(
            samples == 0,
            latency == 0.0,
            "rank {rank}: steal_samples={samples} but steal_latency_us={latency} — \
             the latency books and the sample count disagree"
        );
        survivor_samples += samples;
    }
    assert_eq!(
        fleet.get("steal_samples").and_then(Value::as_u64),
        Some(survivor_samples),
        "fleet sample count must be exactly the survivors' sum"
    );
    std::fs::remove_file(&report).ok();
}

/// Kill a rank right after it writes a credit deposit to rank 0: the
/// deposit may or may not have landed, and the post-mortem reconcile
/// has to balance the books either way.
#[test]
#[ignore = "process fleet: run explicitly via `--ignored --test-threads=1` (see CI)"]
fn tolerant_fleet_survives_a_during_deposit_sigkill_exactly() {
    let report = report_path("during-deposit");
    let out = launch_with_chaos(
        &["--np", "4", "--tolerate-failures", "1", "--report", report.to_str().unwrap()],
        &["uts", "--depth", "8"],
        chaos::DURING_DEPOSIT,
        2,
    );
    assert_success(&out);

    let (result, dead) = result_and_dead(&report);
    assert_eq!(result, UTS_DEPTH_8_NODES);
    assert_eq!(dead, vec![2]);
    std::fs::remove_file(&report).ok();
}
