//! Offline stub of the `xla` crate (PJRT C-API bindings).
//!
//! The build environment has neither crates.io access nor the
//! `xla_extension` shared library, so this stub mirrors the exact API
//! surface `glb::runtime` uses and fails *at the execution boundary*
//! with a clear message. Everything structural still works: a "CPU
//! client" can be constructed (so the engine's manifest handling and the
//! device-service threading are fully testable), but compiling or
//! executing an HLO artifact reports the backend as unavailable.
//!
//! Swapping in the real `xla` crate is a Cargo.toml-only change; the
//! signatures here match the subset of `xla-rs` the engine calls.

use std::fmt;
use std::path::Path;

/// Error type matching the real crate's `Send + Sync` std error.
#[derive(Debug, Clone)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: PJRT backend unavailable (offline `xla` stub; link the real xla crate and run \
         `make artifacts` to enable device execution)"
    ))
}

/// Stub PJRT client. Construction succeeds (it is just a handle); all
/// compilation/execution entry points fail with [`unavailable`].
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Ok(Self { _priv: () })
    }

    pub fn platform_name(&self) -> String {
        "stub-cpu".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("compiling HLO"))
    }

    pub fn buffer_from_host_buffer<T>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(unavailable("uploading host buffer"))
    }
}

/// Stub HLO module proto (text parsing needs the real backend).
pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<Self> {
        Err(unavailable(&format!("parsing HLO text {}", path.as_ref().display())))
    }
}

/// Stub computation wrapper.
pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        Self { _priv: () }
    }
}

/// Stub compiled executable.
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("executing"))
    }

    pub fn execute_b<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("executing (borrowed args)"))
    }
}

/// Stub device buffer.
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("device-to-host copy"))
    }
}

/// Stub host literal.
pub struct Literal {
    _priv: (),
}

impl Literal {
    pub fn vec1<T>(_data: &[T]) -> Self {
        Self { _priv: () }
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable("literal readback"))
    }

    pub fn to_tuple1(&self) -> Result<Literal> {
        Err(unavailable("tuple destructuring"))
    }

    pub fn to_tuple3(&self) -> Result<(Literal, Literal, Literal)> {
        Err(unavailable("tuple destructuring"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_constructs_but_execution_fails() {
        let c = PjRtClient::cpu().unwrap();
        assert_eq!(c.platform_name(), "stub-cpu");
        let comp = XlaComputation::from_proto(&HloModuleProto { _priv: () });
        let err = c.compile(&comp).unwrap_err();
        assert!(err.to_string().contains("PJRT backend unavailable"), "{err}");
    }

    #[test]
    fn error_is_std_error() {
        fn takes_std<E: std::error::Error + Send + Sync + 'static>(_: E) {}
        takes_std(unavailable("x"));
    }
}
