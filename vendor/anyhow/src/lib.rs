//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build environment has no crates.io access, so this vendored shim
//! provides the (small) API surface the repository actually uses:
//!
//! * [`Error`] — a context-chain error type (`{e}` prints the outermost
//!   message, `{e:#}` the whole chain, like anyhow's alternate mode);
//! * [`Result`] with the `E = Error` default;
//! * [`anyhow!`] / [`bail!`] macros;
//! * the [`Context`] extension trait on `Result` and `Option`.
//!
//! Swapping in the real `anyhow` is a Cargo.toml-only change; nothing in
//! the repository depends on shim-specific behaviour.

use std::fmt;

/// A context-chain error. Frame 0 is the outermost context; the last
/// frame is the root cause.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Self { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context frame (what `Context::context` does).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context frames, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // anyhow's `{:#}`: "outer: inner: root".
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for frame in &self.chain[1..] {
                write!(f, "\n    {frame}")?;
            }
        }
        Ok(())
    }
}

// Note: `Error` intentionally does NOT implement `std::error::Error`; that
// is what makes the blanket `From` below (and the twin `Context` impls)
// coherent — the same trick the real anyhow uses.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Self { chain }
    }
}

/// `anyhow::Result` with the usual defaulted error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(...)` / `.with_context(|| ...)`.
pub trait Context<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T, Error> for Result<T, Error> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or any printable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Early-return with an [`Error`] built by [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::core::result::Result::Err($crate::anyhow!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing thing")
    }

    #[test]
    fn display_plain_and_alternate() {
        let e: Error = Result::<(), _>::Err(io_err()).context("reading config").unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        assert!(format!("{e:#}").contains("reading config: missing thing"), "{e:#}");
    }

    #[test]
    fn with_context_on_option() {
        let v: Option<u32> = None;
        let e = v.with_context(|| format!("missing {}", "attr")).unwrap_err();
        assert_eq!(format!("{e}"), "missing attr");
    }

    #[test]
    fn macros_build_errors() {
        let name = "x";
        let e = anyhow!("bad flag --{name}");
        assert_eq!(format!("{e}"), "bad flag --x");
        let e = anyhow!("{} of {}", 1, 2);
        assert_eq!(format!("{e}"), "1 of 2");
        let e = anyhow!(String::from("owned"));
        assert_eq!(format!("{e}"), "owned");
        fn fails() -> Result<()> {
            bail!("nope {}", 7)
        }
        assert_eq!(format!("{}", fails().unwrap_err()), "nope 7");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<u32> {
            let _ = std::fs::metadata("/definitely/not/here/ever")?;
            Ok(1)
        }
        let e = f().unwrap_err();
        assert!(!format!("{e}").is_empty());
    }

    #[test]
    fn context_chains_stack() {
        let e = Error::msg("root").context("mid").context("outer");
        let frames: Vec<_> = e.chain().collect();
        assert_eq!(frames, vec!["outer", "mid", "root"]);
        assert_eq!(e.root_cause(), "root");
    }
}
