//! Minimal offline stand-in for the RustCrypto `sha1` crate.
//!
//! The build environment has no crates.io access, so this vendored shim
//! provides the API surface the repository actually uses:
//!
//! * [`compress`] — the raw SHA-1 compression function over whole
//!   64-byte blocks (the UTS hot path hand-pads a single block and
//!   calls this directly);
//! * [`Sha1`] + [`Digest::digest`] — one-shot hashing of arbitrary
//!   messages (used by tests as the streaming oracle).
//!
//! The implementation is plain FIPS 180-4 SHA-1 and is bit-identical to
//! the real crate's output (pinned against reference vectors below).
//! Swapping in the real `sha1` is a Cargo.toml-only change.

/// One 512-bit message block.
pub type Block = [u8; 64];

/// SHA-1 initial state (FIPS 180-4 §5.3.1).
const IV: [u32; 5] = [0x6745_2301, 0xEFCD_AB89, 0x98BA_DCFE, 0x1032_5476, 0xC3D2_E1F0];

/// Apply the SHA-1 compression function to `state` for each block.
pub fn compress(state: &mut [u32; 5], blocks: &[Block]) {
    for block in blocks {
        compress_block(state, block);
    }
}

fn compress_block(state: &mut [u32; 5], block: &[u8; 64]) {
    let mut w = [0u32; 80];
    for (i, word) in w.iter_mut().take(16).enumerate() {
        *word = u32::from_be_bytes(block[4 * i..4 * i + 4].try_into().unwrap());
    }
    for t in 16..80 {
        w[t] = (w[t - 3] ^ w[t - 8] ^ w[t - 14] ^ w[t - 16]).rotate_left(1);
    }
    let [mut a, mut b, mut c, mut d, mut e] = *state;
    for (t, &wt) in w.iter().enumerate() {
        let (f, k) = match t {
            0..=19 => ((b & c) | (!b & d), 0x5A82_7999u32),
            20..=39 => (b ^ c ^ d, 0x6ED9_EBA1),
            40..=59 => ((b & c) | (b & d) | (c & d), 0x8F1B_BCDC),
            _ => (b ^ c ^ d, 0xCA62_C1D6),
        };
        let temp = a
            .rotate_left(5)
            .wrapping_add(f)
            .wrapping_add(e)
            .wrapping_add(k)
            .wrapping_add(wt);
        e = d;
        d = c;
        c = b.rotate_left(30);
        b = a;
        a = temp;
    }
    state[0] = state[0].wrapping_add(a);
    state[1] = state[1].wrapping_add(b);
    state[2] = state[2].wrapping_add(c);
    state[3] = state[3].wrapping_add(d);
    state[4] = state[4].wrapping_add(e);
}

/// The subset of the RustCrypto `Digest` trait the repository uses.
pub trait Digest {
    /// One-shot hash of `data`.
    fn digest(data: impl AsRef<[u8]>) -> [u8; 20];
}

/// The SHA-1 hasher (one-shot API only).
pub struct Sha1;

impl Digest for Sha1 {
    fn digest(data: impl AsRef<[u8]>) -> [u8; 20] {
        let msg = data.as_ref();
        let mut state = IV;
        let mut blocks = msg.chunks_exact(64);
        for block in blocks.by_ref() {
            compress_block(&mut state, block.try_into().unwrap());
        }
        // Padding (§5.1.1): 0x80, zeros, 64-bit big-endian bit length —
        // one tail block if the remainder leaves >= 9 free bytes, else two.
        let rem = blocks.remainder();
        let bit_len = (msg.len() as u64) * 8;
        let tail_blocks = if rem.len() + 9 <= 64 { 1 } else { 2 };
        let mut tail = [0u8; 128];
        tail[..rem.len()].copy_from_slice(rem);
        tail[rem.len()] = 0x80;
        tail[tail_blocks * 64 - 8..tail_blocks * 64].copy_from_slice(&bit_len.to_be_bytes());
        for block in tail[..tail_blocks * 64].chunks_exact(64) {
            compress_block(&mut state, block.try_into().unwrap());
        }
        let mut out = [0u8; 20];
        for (i, word) in state.iter().enumerate() {
            out[i * 4..i * 4 + 4].copy_from_slice(&word.to_be_bytes());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hex(d: [u8; 20]) -> String {
        d.iter().map(|b| format!("{b:02x}")).collect()
    }

    #[test]
    fn reference_vectors() {
        // Pinned against Python's hashlib (real SHA-1).
        assert_eq!(hex(Sha1::digest([0u8; 0])), "da39a3ee5e6b4b0d3255bfef95601890afd80709");
        assert_eq!(hex(Sha1::digest(b"abc")), "a9993e364706816aba3e25717850c26c9cd0d89d");
        assert_eq!(hex(Sha1::digest([0u8; 20])), "6768033e216468247bd031a0a2d9876d79818f8f");
        // Padding edges: 55 bytes (one tail block), 56 (two), 64 (exact).
        assert_eq!(hex(Sha1::digest([b'a'; 55])), "c1c8bbdc22796e28c0e15163d20899b65621d65a");
        assert_eq!(hex(Sha1::digest([b'a'; 56])), "c2db330f6083854c99d4b5bfb6e8f29f201be699");
        assert_eq!(hex(Sha1::digest([0u8; 64])), "c8d7d0ef0eedfa82d2ea1aa592845b9a6d4b02b7");
        // Multi-block message.
        let long: Vec<u8> = (0..100u8).collect();
        assert_eq!(hex(Sha1::digest(&long)), "1e6634bfaebc0348298105923d0f26e47aa33ff5");
    }

    #[test]
    fn compress_matches_digest_for_hand_padded_block() {
        // The UTS hot path pads a short message by hand and calls
        // `compress` directly; that must equal the streaming digest.
        let msg = [7u8; 24];
        let mut block = [0u8; 64];
        block[..24].copy_from_slice(&msg);
        block[24] = 0x80;
        block[56..].copy_from_slice(&(24u64 * 8).to_be_bytes());
        let mut state = IV;
        compress(&mut state, &[block]);
        let mut out = [0u8; 20];
        for (i, word) in state.iter().enumerate() {
            out[i * 4..i * 4 + 4].copy_from_slice(&word.to_be_bytes());
        }
        assert_eq!(out, Sha1::digest(msg));
    }
}
